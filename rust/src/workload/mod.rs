//! Workload layer: the Spec-Bench stand-in (DESIGN.md §1).
//!
//! Prompts come from `artifacts/workloads.json` — held-out documents from
//! the same five task-family generators the model was trained on, exported
//! by `python/compile/aot.py` so the rust and python sides agree exactly on
//! the token distribution. This module samples per-task request sets,
//! synthesizes arrival processes for the serving benchmarks, and composes
//! both into named serving *scenarios* ([`ScenarioKind`]/[`ScenarioPlan`])
//! — multi-turn agentic loops, bursty diurnal replay, long-context
//! summarization, an adversarial cache-thrashing mix — that
//! `serve_benchmark` runs and reports against p50/p99 TTFT/TPOT SLOs.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::coordinator::GenParams;
use crate::tokenizer::BOS_ID;
use crate::util::json::{parse_file, Json};
use crate::util::rng::Pcg;

/// The paper's five task families (Table 1 columns).
pub const TASKS: [&str; 5] = ["mtbench", "humaneval", "gsm8k", "alpaca", "cnndm"];

/// One serving prompt with its reference completion.
#[derive(Debug, Clone)]
pub struct WorkItem {
    pub task: String,
    pub prompt: String,
    pub prompt_ids: Vec<i32>,
    pub reference_ids: Vec<i32>,
}

/// The full exported workload set.
#[derive(Debug, Clone)]
pub struct WorkloadSet {
    items: Vec<WorkItem>,
}

impl WorkloadSet {
    pub fn load(path: &Path) -> Result<Self> {
        let j = parse_file(path).context("loading workloads.json")?;
        Self::from_json(&j)
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        let mut items = Vec::new();
        for (task, arr) in j.get("tasks")?.as_obj()? {
            for it in arr.as_arr()? {
                items.push(WorkItem {
                    task: task.clone(),
                    prompt: it.get("prompt")?.as_str()?.to_string(),
                    prompt_ids: it.get("prompt_ids")?.as_i32_vec()?,
                    reference_ids: it.get("reference_ids")?.as_i32_vec()?,
                });
            }
        }
        Ok(WorkloadSet { items })
    }

    pub fn len(&self) -> usize {
        self.items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    pub fn task_items(&self, task: &str) -> Vec<&WorkItem> {
        self.items.iter().filter(|i| i.task == task).collect()
    }

    /// Task names present in this set (diagnostics for bad `--task` flags).
    pub fn task_names(&self) -> Vec<String> {
        let mut names: Vec<String> = self.items.iter().map(|i| i.task.clone()).collect();
        names.sort();
        names.dedup();
        names
    }

    /// Non-empty item pool for one task, or an actionable error (a mistyped
    /// benchmark flag should fail with a message, not a panic).
    fn task_pool(&self, task: &str) -> Result<Vec<&WorkItem>> {
        let pool = self.task_items(task);
        if pool.is_empty() {
            bail!(
                "no workload items for task '{task}' (exported tasks: {})",
                self.task_names().join(", ")
            );
        }
        Ok(pool)
    }

    /// Deterministically sample `n` prompts of one task.
    pub fn sample(&self, task: &str, n: usize, rng: &mut Pcg) -> Result<Vec<WorkItem>> {
        let pool = self.task_pool(task)?;
        Ok((0..n)
            .map(|_| pool[rng.usize_below(pool.len())].clone())
            .collect())
    }

    /// A mixed-task batch in round-robin task order (the serving driver).
    pub fn mixed(&self, n: usize, rng: &mut Pcg) -> Result<Vec<WorkItem>> {
        (0..n)
            .map(|i| {
                let pool = self.task_pool(TASKS[i % TASKS.len()])?;
                Ok(pool[rng.usize_below(pool.len())].clone())
            })
            .collect()
    }

    /// The per-task system-prompt templates [`WorkloadSet::shared_prefix`]
    /// prepends — each family's first exported item cut to `prefix_len`
    /// tokens, as `(task, template_ids)` pairs. Boot warm-up feeds these to
    /// the engine's prefix cache so the *first* request of every family
    /// already admits warm.
    pub fn templates(&self, prefix_len: usize) -> Result<Vec<(String, Vec<i32>)>> {
        TASKS
            .iter()
            .map(|task| {
                let pool = self.task_pool(task)?;
                let ids: Vec<i32> =
                    pool[0].prompt_ids.iter().copied().take(prefix_len).collect();
                Ok((task.to_string(), ids))
            })
            .collect()
    }

    /// A shared-prefix serving batch: each task family gets a fixed
    /// "system prompt" template (the family's first exported item, cut to
    /// `prefix_len` tokens) that is prepended to every sampled prompt of
    /// that family, so requests within a family share a long common token
    /// prefix — the shape the engine's prefix cache turns into suffix-only
    /// prefill. Round-robin over task families like [`WorkloadSet::mixed`].
    ///
    /// The sampled item's leading `<bos>` is stripped before concatenation
    /// so the combined sequence reads like one prompt (a single `<bos>`
    /// from the template). The `prompt` text is rebuilt to match the
    /// truncated ids exactly: the closed-lexicon tokenizer maps every
    /// non-special id to one whitespace word, so the kept template ids
    /// correspond to that many leading words of the template text — the
    /// text<->ids round trip stays exact on the wire path.
    pub fn shared_prefix(&self, n: usize, prefix_len: usize,
                         rng: &mut Pcg) -> Result<Vec<WorkItem>> {
        (0..n)
            .map(|i| {
                let task = TASKS[i % TASKS.len()];
                let pool = self.task_pool(task)?;
                let template = pool[0];
                let it = pool[rng.usize_below(pool.len())];
                let tpl_ids: Vec<i32> = template
                    .prompt_ids
                    .iter()
                    .copied()
                    .take(prefix_len)
                    .collect();
                let tpl_words = tpl_ids
                    .iter()
                    .filter(|&&t| t != BOS_ID && t != crate::tokenizer::PAD_ID
                        && t != crate::tokenizer::EOS_ID)
                    .count();
                let tpl_text = template
                    .prompt
                    .split_whitespace()
                    .take(tpl_words)
                    .collect::<Vec<_>>()
                    .join(" ");
                let mut prompt_ids = tpl_ids;
                let body = it
                    .prompt_ids
                    .strip_prefix(&[BOS_ID])
                    .unwrap_or(it.prompt_ids.as_slice());
                prompt_ids.extend_from_slice(body);
                Ok(WorkItem {
                    task: task.to_string(),
                    prompt: format!("{tpl_text} {}", it.prompt).trim().to_string(),
                    prompt_ids,
                    reference_ids: it.reference_ids.clone(),
                })
            })
            .collect()
    }
}

impl WorkloadSet {
    /// A long-context summarization batch: `depth` documents of the
    /// summarization family concatenated into one prompt per request (the
    /// reference completion is the last document's). Stresses prefill
    /// volume and KV residency rather than cache reuse.
    pub fn long_context(&self, n: usize, depth: usize,
                        rng: &mut Pcg) -> Result<Vec<WorkItem>> {
        let pool = self.task_pool("cnndm")?;
        (0..n)
            .map(|_| {
                let mut prompt_ids = vec![BOS_ID];
                let mut texts: Vec<String> = Vec::new();
                let mut reference_ids = Vec::new();
                for _ in 0..depth.max(1) {
                    let it = pool[rng.usize_below(pool.len())];
                    let body = it
                        .prompt_ids
                        .strip_prefix(&[BOS_ID])
                        .unwrap_or(it.prompt_ids.as_slice());
                    prompt_ids.extend_from_slice(body);
                    texts.push(it.prompt.clone());
                    reference_ids = it.reference_ids.clone();
                }
                Ok(WorkItem {
                    task: "cnndm".to_string(),
                    prompt: texts.join(" "),
                    prompt_ids,
                    reference_ids,
                })
            })
            .collect()
    }

    /// An adversarial cache-thrashing mix: every request carries a distinct
    /// per-request "salt" prefix — `salt_len` of the item's own body words,
    /// rotated by a per-request offset — so same-family requests share no
    /// useful common prefix and the prefix cache fills with entries that
    /// never hit again. Word/id pairs rotate together, so the prompt text
    /// still encodes to exactly `prompt_ids` on the wire path (the closed
    /// lexicon maps each non-special id to one whitespace word).
    pub fn thrash(&self, n: usize, salt_len: usize,
                  rng: &mut Pcg) -> Result<Vec<WorkItem>> {
        (0..n)
            .map(|i| {
                let task = TASKS[i % TASKS.len()];
                let pool = self.task_pool(task)?;
                let it = pool[rng.usize_below(pool.len())];
                let body = it
                    .prompt_ids
                    .strip_prefix(&[BOS_ID])
                    .unwrap_or(it.prompt_ids.as_slice());
                let pairs: Vec<(i32, &str)> = body
                    .iter()
                    .copied()
                    .filter(|&t| t != crate::tokenizer::PAD_ID
                        && t != crate::tokenizer::EOS_ID)
                    .zip(it.prompt.split_whitespace())
                    .collect();
                if pairs.is_empty() {
                    return Ok(it.clone());
                }
                // Deterministic per-request rotation: distinct salts even
                // when the rng resamples the same pool item back to back.
                let rot = (i * 7 + 1) % pairs.len();
                let salt: Vec<(i32, &str)> = pairs
                    .iter()
                    .cycle()
                    .skip(rot)
                    .take(salt_len.max(1).min(pairs.len()))
                    .copied()
                    .collect();
                let mut prompt_ids = vec![BOS_ID];
                prompt_ids.extend(salt.iter().map(|&(t, _)| t));
                prompt_ids.extend_from_slice(body);
                let salt_text =
                    salt.iter().map(|&(_, w)| w).collect::<Vec<_>>().join(" ");
                Ok(WorkItem {
                    task: task.to_string(),
                    prompt: format!("{salt_text} {}", it.prompt).trim().to_string(),
                    prompt_ids,
                    reference_ids: it.reference_ids.clone(),
                })
            })
            .collect()
    }

    /// Compose items + arrivals + turn structure for one named scenario.
    /// `n` is the conversation count, `prefix_len` the shared-template cut
    /// (agentic) / salt length (thrash), `rate_per_s` the open-loop mean
    /// arrival rate where the scenario replays a trace (0 = closed loop
    /// even for trace scenarios).
    pub fn scenario(&self, kind: ScenarioKind, n: usize, prefix_len: usize,
                    rate_per_s: f64, rng: &mut Pcg) -> Result<ScenarioPlan> {
        let plan = match kind {
            ScenarioKind::Mixed => ScenarioPlan {
                kind,
                items: self.mixed(n, rng)?,
                arrivals: Vec::new(),
                turns: 1,
            },
            // Agentic tool-call loop: family-templated prompts, each
            // conversation resubmitted for several turns with the prior
            // output appended (the driver owns the append) — the shape the
            // prefix cache's mid-stream snapshots and the per-class gamma
            // prior exist for.
            ScenarioKind::Agentic => ScenarioPlan {
                kind,
                items: self.shared_prefix(n, prefix_len, rng)?,
                arrivals: Vec::new(),
                turns: 3,
            },
            ScenarioKind::Diurnal => {
                let items = self.mixed(n, rng)?;
                let arrivals = if rate_per_s > 0.0 {
                    // Period ≈ a quarter of the expected trace duration
                    // (mean rate over a cycle is 2.5× base at peak 4.0),
                    // so the replay traverses several full day/night
                    // cycles instead of one slow ramp.
                    let period = (n as f64 / (10.0 * rate_per_s)).max(0.5);
                    ArrivalTrace::diurnal(n, rate_per_s, 4.0, period, rng)
                        .arrivals
                        .iter()
                        .map(|a| a.0)
                        .collect()
                } else {
                    Vec::new()
                };
                ScenarioPlan { kind, items, arrivals, turns: 1 }
            }
            ScenarioKind::LongCtx => ScenarioPlan {
                kind,
                items: self.long_context(n, 4, rng)?,
                arrivals: Vec::new(),
                turns: 1,
            },
            ScenarioKind::Thrash => ScenarioPlan {
                kind,
                items: self.thrash(n, prefix_len.max(4), rng)?,
                arrivals: Vec::new(),
                turns: 1,
            },
        };
        Ok(plan)
    }
}

/// The serving scenario suite `serve_benchmark --scenario` selects from.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScenarioKind {
    /// Round-robin mixed-task closed loop (the original benchmark shape).
    Mixed,
    /// Multi-turn agentic/tool-call loops over family-shared templates.
    Agentic,
    /// Bursty diurnal trace replay: rate-modulated Poisson arrivals.
    Diurnal,
    /// Long-context summarization: several documents per prompt.
    LongCtx,
    /// Adversarial cache-thrashing mix: per-request salted prefixes.
    Thrash,
}

impl ScenarioKind {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "mixed" => ScenarioKind::Mixed,
            "agentic" => ScenarioKind::Agentic,
            "diurnal" => ScenarioKind::Diurnal,
            "longctx" => ScenarioKind::LongCtx,
            "thrash" => ScenarioKind::Thrash,
            other => bail!(
                "unknown scenario '{other}' (expected one of: {})",
                ScenarioKind::all()
                    .iter()
                    .map(|k| k.name())
                    .collect::<Vec<_>>()
                    .join(", ")
            ),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            ScenarioKind::Mixed => "mixed",
            ScenarioKind::Agentic => "agentic",
            ScenarioKind::Diurnal => "diurnal",
            ScenarioKind::LongCtx => "longctx",
            ScenarioKind::Thrash => "thrash",
        }
    }

    pub fn all() -> [ScenarioKind; 5] {
        [
            ScenarioKind::Mixed,
            ScenarioKind::Agentic,
            ScenarioKind::Diurnal,
            ScenarioKind::LongCtx,
            ScenarioKind::Thrash,
        ]
    }
}

/// One scenario's executable shape: what to send, when, and how many turns
/// per conversation.
#[derive(Debug, Clone)]
pub struct ScenarioPlan {
    pub kind: ScenarioKind,
    /// One entry per conversation (turn 1's prompt; later turns append).
    pub items: Vec<WorkItem>,
    /// Arrival offset seconds per conversation; empty = closed loop.
    pub arrivals: Vec<f64>,
    /// Turns per conversation (>1 = resubmit with the output appended).
    pub turns: usize,
}

/// Open-loop Poisson arrival trace for the serving benchmark.
#[derive(Debug, Clone)]
pub struct ArrivalTrace {
    /// (arrival offset seconds, item index)
    pub arrivals: Vec<(f64, usize)>,
}

impl ArrivalTrace {
    pub fn poisson(n: usize, rate_per_s: f64, rng: &mut Pcg) -> Self {
        let mut t = 0.0;
        let arrivals = (0..n)
            .map(|i| {
                t += rng.exp(rate_per_s);
                (t, i)
            })
            .collect();
        ArrivalTrace { arrivals }
    }

    /// Rate-modulated Poisson replay of a diurnal load curve: the
    /// instantaneous rate swings sinusoidally between `base_rate_per_s`
    /// and `peak_ratio`× it over `period_s`, so the trace alternates calm
    /// troughs with bursts that exceed the mean rate — the shape that
    /// separates p99 from p50 under an SLO.
    pub fn diurnal(n: usize, base_rate_per_s: f64, peak_ratio: f64,
                   period_s: f64, rng: &mut Pcg) -> Self {
        let mut t = 0.0;
        let arrivals = (0..n)
            .map(|i| {
                let phase = t / period_s.max(1e-9) * std::f64::consts::TAU;
                let swing = 0.5 * (1.0 + phase.sin());
                let rate =
                    base_rate_per_s * (1.0 + (peak_ratio - 1.0) * swing);
                t += rng.exp(rate.max(1e-9));
                (t, i)
            })
            .collect();
        ArrivalTrace { arrivals }
    }

    pub fn duration(&self) -> f64 {
        self.arrivals.last().map(|a| a.0).unwrap_or(0.0)
    }
}

/// Default generation params used by the benches (paper: greedy T=0 and
/// sampled T=1, ~64 new tokens per request on the scaled-down model).
pub fn bench_params(temp: f64, max_new: usize) -> GenParams {
    GenParams { temp, max_new, ..GenParams::default() }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::parse;

    fn sample_json() -> Json {
        parse(
            r#"{"tasks": {
                "gsm8k": [
                  {"prompt":"question : a","prompt_ids":[1,10],"reference":"r","reference_ids":[11]},
                  {"prompt":"question : b","prompt_ids":[1,12],"reference":"r","reference_ids":[13]}
                ],
                "alpaca": [
                  {"prompt":"write","prompt_ids":[1,20],"reference":"r","reference_ids":[21]}
                ],
                "mtbench": [{"prompt":"m","prompt_ids":[1,30],"reference":"r","reference_ids":[31]}],
                "humaneval": [{"prompt":"h","prompt_ids":[1,40],"reference":"r","reference_ids":[41]}],
                "cnndm": [{"prompt":"c","prompt_ids":[1,50],"reference":"r","reference_ids":[51]}]
            }, "seed": 1}"#,
        )
        .unwrap()
    }

    #[test]
    fn loads_and_filters_by_task() {
        let ws = WorkloadSet::from_json(&sample_json()).unwrap();
        assert_eq!(ws.len(), 6);
        assert_eq!(ws.task_items("gsm8k").len(), 2);
        assert_eq!(ws.task_items("alpaca").len(), 1);
    }

    #[test]
    fn sampling_is_deterministic_per_seed() {
        let ws = WorkloadSet::from_json(&sample_json()).unwrap();
        let a: Vec<_> = ws.sample("gsm8k", 8, &mut Pcg::seeded(5)).unwrap()
            .iter().map(|i| i.prompt_ids.clone()).collect();
        let b: Vec<_> = ws.sample("gsm8k", 8, &mut Pcg::seeded(5)).unwrap()
            .iter().map(|i| i.prompt_ids.clone()).collect();
        assert_eq!(a, b);
    }

    #[test]
    fn unknown_task_is_an_error_not_a_panic() {
        let ws = WorkloadSet::from_json(&sample_json()).unwrap();
        let err = ws.sample("gsm9k", 4, &mut Pcg::seeded(5)).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("gsm9k"), "message names the bad task: {msg}");
        assert!(msg.contains("gsm8k"), "message lists the exported tasks: {msg}");
        assert!(ws.shared_prefix(3, 1, &mut Pcg::seeded(5)).is_ok());
        // an empty set fails through mixed/shared_prefix too
        let empty = WorkloadSet { items: Vec::new() };
        assert!(empty.mixed(2, &mut Pcg::seeded(1)).is_err());
        assert!(empty.shared_prefix(2, 1, &mut Pcg::seeded(1)).is_err());
    }

    #[test]
    fn mixed_covers_all_tasks() {
        let ws = WorkloadSet::from_json(&sample_json()).unwrap();
        let m = ws.mixed(10, &mut Pcg::seeded(1)).unwrap();
        for t in TASKS {
            assert!(m.iter().any(|i| i.task == t), "missing {t}");
        }
    }

    #[test]
    fn templates_are_exactly_the_shared_prefix_prefixes() {
        let ws = WorkloadSet::from_json(&sample_json()).unwrap();
        let templates = ws.templates(2).unwrap();
        assert_eq!(templates.len(), TASKS.len());
        let items = ws.shared_prefix(10, 2, &mut Pcg::seeded(9)).unwrap();
        for it in &items {
            let (_, tpl) = templates
                .iter()
                .find(|(task, _)| *task == it.task)
                .expect("template for every task");
            assert!(
                it.prompt_ids.starts_with(tpl),
                "warm-up template must be the exact served prefix"
            );
        }
        // Unknown-task plumbing matches the rest of the set's error style.
        let empty = WorkloadSet { items: Vec::new() };
        assert!(empty.templates(2).is_err());
    }

    #[test]
    fn shared_prefix_items_share_their_family_template() {
        let ws = WorkloadSet::from_json(&sample_json()).unwrap();
        let items = ws.shared_prefix(10, 2, &mut Pcg::seeded(3)).unwrap();
        assert_eq!(items.len(), 10);
        for (i, it) in items.iter().enumerate() {
            assert_eq!(it.task, TASKS[i % TASKS.len()], "round-robin task order");
            let template: Vec<i32> = ws.task_items(&it.task)[0]
                .prompt_ids
                .iter()
                .copied()
                .take(2)
                .collect();
            assert!(
                it.prompt_ids.starts_with(&template),
                "item {i} does not share its family template"
            );
            assert!(it.prompt_ids.len() > template.len(), "body appended");
            // exactly one leading <bos>: the sampled item's was stripped
            assert_eq!(it.prompt_ids.iter().filter(|&&t| t == 1).count(), 1);
        }
        // same seed, same batch
        let again = ws.shared_prefix(10, 2, &mut Pcg::seeded(3)).unwrap();
        let a: Vec<_> = items.iter().map(|i| i.prompt_ids.clone()).collect();
        let b: Vec<_> = again.iter().map(|i| i.prompt_ids.clone()).collect();
        assert_eq!(a, b);
    }

    #[test]
    fn scenario_names_round_trip_and_bad_names_error() {
        for k in ScenarioKind::all() {
            assert_eq!(ScenarioKind::parse(k.name()).unwrap(), k);
        }
        let err = ScenarioKind::parse("weekday").unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("weekday"), "names the bad scenario: {msg}");
        assert!(msg.contains("agentic"), "lists the suite: {msg}");
    }

    #[test]
    fn agentic_scenario_is_multi_turn_over_shared_templates() {
        let ws = WorkloadSet::from_json(&sample_json()).unwrap();
        let plan = ws
            .scenario(ScenarioKind::Agentic, 10, 2, 0.0, &mut Pcg::seeded(3))
            .unwrap();
        assert!(plan.turns > 1, "agentic loops must resubmit turns");
        assert!(plan.arrivals.is_empty(), "closed loop");
        assert_eq!(plan.items.len(), 10);
        for (i, it) in plan.items.iter().enumerate() {
            let template: Vec<i32> = ws.task_items(&it.task)[0]
                .prompt_ids
                .iter()
                .copied()
                .take(2)
                .collect();
            assert!(it.prompt_ids.starts_with(&template), "item {i}");
        }
    }

    #[test]
    fn diurnal_scenario_replays_a_bursty_trace() {
        let ws = WorkloadSet::from_json(&sample_json()).unwrap();
        let plan = ws
            .scenario(ScenarioKind::Diurnal, 400, 2, 8.0, &mut Pcg::seeded(4))
            .unwrap();
        assert_eq!(plan.arrivals.len(), 400);
        assert!(plan.arrivals.windows(2).all(|w| w[0] <= w[1]), "monotone");
        // Burstiness: the peak-rate half of the cycle packs arrivals
        // tighter than a flat-rate trace would — gap dispersion well above
        // the exponential's.
        let gaps: Vec<f64> = plan
            .arrivals
            .windows(2)
            .map(|w| w[1] - w[0])
            .collect();
        let mean = gaps.iter().sum::<f64>() / gaps.len() as f64;
        let var =
            gaps.iter().map(|g| (g - mean) * (g - mean)).sum::<f64>()
                / gaps.len() as f64;
        let cv2 = var / (mean * mean);
        assert!(cv2 > 1.1, "diurnal gaps must be over-dispersed, cv² {cv2}");
        // rate 0 = closed loop even for the trace scenario
        let closed = ws
            .scenario(ScenarioKind::Diurnal, 10, 2, 0.0, &mut Pcg::seeded(4))
            .unwrap();
        assert!(closed.arrivals.is_empty());
    }

    #[test]
    fn long_context_concatenates_documents() {
        let ws = WorkloadSet::from_json(&sample_json()).unwrap();
        let single = ws.task_items("cnndm")[0].prompt_ids.len();
        let plan = ws
            .scenario(ScenarioKind::LongCtx, 4, 2, 0.0, &mut Pcg::seeded(5))
            .unwrap();
        for it in &plan.items {
            assert_eq!(it.task, "cnndm");
            assert!(
                it.prompt_ids.len() > single,
                "long-context prompt must exceed one document"
            );
            assert_eq!(it.prompt_ids.iter().filter(|&&t| t == 1).count(), 1);
        }
    }

    #[test]
    fn thrash_salts_break_prefix_sharing() {
        let ws = WorkloadSet::from_json(&sample_json()).unwrap();
        let items = ws.thrash(10, 2, &mut Pcg::seeded(6)).unwrap();
        assert_eq!(items.len(), 10);
        for it in &items {
            assert_eq!(it.prompt_ids[0], 1, "BOS preserved");
            // salt + body: longer than the plain item
            let plain = ws.task_items(&it.task)[0].prompt_ids.len();
            assert!(it.prompt_ids.len() >= plain);
        }
        // Same-family consecutive requests must not share their salted
        // prefix (the whole point of the adversarial mix). The fixture's
        // bodies are one token, so salts of the same item still rotate to
        // distinct positions only when the body has >1 word; assert on the
        // gsm8k family which has two items to alternate between.
        let a = ws.thrash(20, 2, &mut Pcg::seeded(6)).unwrap();
        let b = ws.thrash(20, 2, &mut Pcg::seeded(6)).unwrap();
        assert!(
            a.iter()
                .zip(&b)
                .all(|(x, y)| x.prompt_ids == y.prompt_ids),
            "deterministic per seed"
        );
    }

    #[test]
    fn poisson_arrivals_monotone_with_correct_mean() {
        let mut rng = Pcg::seeded(2);
        let tr = ArrivalTrace::poisson(4000, 8.0, &mut rng);
        assert!(tr.arrivals.windows(2).all(|w| w[0].0 <= w[1].0));
        let mean_gap = tr.duration() / 4000.0;
        assert!((mean_gap - 0.125).abs() < 0.01, "mean gap {mean_gap}");
    }
}

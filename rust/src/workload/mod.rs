//! Workload layer: the Spec-Bench stand-in (DESIGN.md §1).
//!
//! Prompts come from `artifacts/workloads.json` — held-out documents from
//! the same five task-family generators the model was trained on, exported
//! by `python/compile/aot.py` so the rust and python sides agree exactly on
//! the token distribution. This module samples per-task request sets and
//! synthesizes arrival processes for the serving benchmarks.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::coordinator::GenParams;
use crate::tokenizer::BOS_ID;
use crate::util::json::{parse_file, Json};
use crate::util::rng::Pcg;

/// The paper's five task families (Table 1 columns).
pub const TASKS: [&str; 5] = ["mtbench", "humaneval", "gsm8k", "alpaca", "cnndm"];

/// One serving prompt with its reference completion.
#[derive(Debug, Clone)]
pub struct WorkItem {
    pub task: String,
    pub prompt: String,
    pub prompt_ids: Vec<i32>,
    pub reference_ids: Vec<i32>,
}

/// The full exported workload set.
#[derive(Debug, Clone)]
pub struct WorkloadSet {
    items: Vec<WorkItem>,
}

impl WorkloadSet {
    pub fn load(path: &Path) -> Result<Self> {
        let j = parse_file(path).context("loading workloads.json")?;
        Self::from_json(&j)
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        let mut items = Vec::new();
        for (task, arr) in j.get("tasks")?.as_obj()? {
            for it in arr.as_arr()? {
                items.push(WorkItem {
                    task: task.clone(),
                    prompt: it.get("prompt")?.as_str()?.to_string(),
                    prompt_ids: it.get("prompt_ids")?.as_i32_vec()?,
                    reference_ids: it.get("reference_ids")?.as_i32_vec()?,
                });
            }
        }
        Ok(WorkloadSet { items })
    }

    pub fn len(&self) -> usize {
        self.items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    pub fn task_items(&self, task: &str) -> Vec<&WorkItem> {
        self.items.iter().filter(|i| i.task == task).collect()
    }

    /// Task names present in this set (diagnostics for bad `--task` flags).
    pub fn task_names(&self) -> Vec<String> {
        let mut names: Vec<String> = self.items.iter().map(|i| i.task.clone()).collect();
        names.sort();
        names.dedup();
        names
    }

    /// Non-empty item pool for one task, or an actionable error (a mistyped
    /// benchmark flag should fail with a message, not a panic).
    fn task_pool(&self, task: &str) -> Result<Vec<&WorkItem>> {
        let pool = self.task_items(task);
        if pool.is_empty() {
            bail!(
                "no workload items for task '{task}' (exported tasks: {})",
                self.task_names().join(", ")
            );
        }
        Ok(pool)
    }

    /// Deterministically sample `n` prompts of one task.
    pub fn sample(&self, task: &str, n: usize, rng: &mut Pcg) -> Result<Vec<WorkItem>> {
        let pool = self.task_pool(task)?;
        Ok((0..n)
            .map(|_| pool[rng.usize_below(pool.len())].clone())
            .collect())
    }

    /// A mixed-task batch in round-robin task order (the serving driver).
    pub fn mixed(&self, n: usize, rng: &mut Pcg) -> Result<Vec<WorkItem>> {
        (0..n)
            .map(|i| {
                let pool = self.task_pool(TASKS[i % TASKS.len()])?;
                Ok(pool[rng.usize_below(pool.len())].clone())
            })
            .collect()
    }

    /// The per-task system-prompt templates [`WorkloadSet::shared_prefix`]
    /// prepends — each family's first exported item cut to `prefix_len`
    /// tokens, as `(task, template_ids)` pairs. Boot warm-up feeds these to
    /// the engine's prefix cache so the *first* request of every family
    /// already admits warm.
    pub fn templates(&self, prefix_len: usize) -> Result<Vec<(String, Vec<i32>)>> {
        TASKS
            .iter()
            .map(|task| {
                let pool = self.task_pool(task)?;
                let ids: Vec<i32> =
                    pool[0].prompt_ids.iter().copied().take(prefix_len).collect();
                Ok((task.to_string(), ids))
            })
            .collect()
    }

    /// A shared-prefix serving batch: each task family gets a fixed
    /// "system prompt" template (the family's first exported item, cut to
    /// `prefix_len` tokens) that is prepended to every sampled prompt of
    /// that family, so requests within a family share a long common token
    /// prefix — the shape the engine's prefix cache turns into suffix-only
    /// prefill. Round-robin over task families like [`WorkloadSet::mixed`].
    ///
    /// The sampled item's leading `<bos>` is stripped before concatenation
    /// so the combined sequence reads like one prompt (a single `<bos>`
    /// from the template). The `prompt` text is rebuilt to match the
    /// truncated ids exactly: the closed-lexicon tokenizer maps every
    /// non-special id to one whitespace word, so the kept template ids
    /// correspond to that many leading words of the template text — the
    /// text<->ids round trip stays exact on the wire path.
    pub fn shared_prefix(&self, n: usize, prefix_len: usize,
                         rng: &mut Pcg) -> Result<Vec<WorkItem>> {
        (0..n)
            .map(|i| {
                let task = TASKS[i % TASKS.len()];
                let pool = self.task_pool(task)?;
                let template = pool[0];
                let it = pool[rng.usize_below(pool.len())];
                let tpl_ids: Vec<i32> = template
                    .prompt_ids
                    .iter()
                    .copied()
                    .take(prefix_len)
                    .collect();
                let tpl_words = tpl_ids
                    .iter()
                    .filter(|&&t| t != BOS_ID && t != crate::tokenizer::PAD_ID
                        && t != crate::tokenizer::EOS_ID)
                    .count();
                let tpl_text = template
                    .prompt
                    .split_whitespace()
                    .take(tpl_words)
                    .collect::<Vec<_>>()
                    .join(" ");
                let mut prompt_ids = tpl_ids;
                let body = it
                    .prompt_ids
                    .strip_prefix(&[BOS_ID])
                    .unwrap_or(it.prompt_ids.as_slice());
                prompt_ids.extend_from_slice(body);
                Ok(WorkItem {
                    task: task.to_string(),
                    prompt: format!("{tpl_text} {}", it.prompt).trim().to_string(),
                    prompt_ids,
                    reference_ids: it.reference_ids.clone(),
                })
            })
            .collect()
    }
}

/// Open-loop Poisson arrival trace for the serving benchmark.
#[derive(Debug, Clone)]
pub struct ArrivalTrace {
    /// (arrival offset seconds, item index)
    pub arrivals: Vec<(f64, usize)>,
}

impl ArrivalTrace {
    pub fn poisson(n: usize, rate_per_s: f64, rng: &mut Pcg) -> Self {
        let mut t = 0.0;
        let arrivals = (0..n)
            .map(|i| {
                t += rng.exp(rate_per_s);
                (t, i)
            })
            .collect();
        ArrivalTrace { arrivals }
    }

    pub fn duration(&self) -> f64 {
        self.arrivals.last().map(|a| a.0).unwrap_or(0.0)
    }
}

/// Default generation params used by the benches (paper: greedy T=0 and
/// sampled T=1, ~64 new tokens per request on the scaled-down model).
pub fn bench_params(temp: f64, max_new: usize) -> GenParams {
    GenParams { temp, max_new, ..GenParams::default() }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::parse;

    fn sample_json() -> Json {
        parse(
            r#"{"tasks": {
                "gsm8k": [
                  {"prompt":"question : a","prompt_ids":[1,10],"reference":"r","reference_ids":[11]},
                  {"prompt":"question : b","prompt_ids":[1,12],"reference":"r","reference_ids":[13]}
                ],
                "alpaca": [
                  {"prompt":"write","prompt_ids":[1,20],"reference":"r","reference_ids":[21]}
                ],
                "mtbench": [{"prompt":"m","prompt_ids":[1,30],"reference":"r","reference_ids":[31]}],
                "humaneval": [{"prompt":"h","prompt_ids":[1,40],"reference":"r","reference_ids":[41]}],
                "cnndm": [{"prompt":"c","prompt_ids":[1,50],"reference":"r","reference_ids":[51]}]
            }, "seed": 1}"#,
        )
        .unwrap()
    }

    #[test]
    fn loads_and_filters_by_task() {
        let ws = WorkloadSet::from_json(&sample_json()).unwrap();
        assert_eq!(ws.len(), 6);
        assert_eq!(ws.task_items("gsm8k").len(), 2);
        assert_eq!(ws.task_items("alpaca").len(), 1);
    }

    #[test]
    fn sampling_is_deterministic_per_seed() {
        let ws = WorkloadSet::from_json(&sample_json()).unwrap();
        let a: Vec<_> = ws.sample("gsm8k", 8, &mut Pcg::seeded(5)).unwrap()
            .iter().map(|i| i.prompt_ids.clone()).collect();
        let b: Vec<_> = ws.sample("gsm8k", 8, &mut Pcg::seeded(5)).unwrap()
            .iter().map(|i| i.prompt_ids.clone()).collect();
        assert_eq!(a, b);
    }

    #[test]
    fn unknown_task_is_an_error_not_a_panic() {
        let ws = WorkloadSet::from_json(&sample_json()).unwrap();
        let err = ws.sample("gsm9k", 4, &mut Pcg::seeded(5)).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("gsm9k"), "message names the bad task: {msg}");
        assert!(msg.contains("gsm8k"), "message lists the exported tasks: {msg}");
        assert!(ws.shared_prefix(3, 1, &mut Pcg::seeded(5)).is_ok());
        // an empty set fails through mixed/shared_prefix too
        let empty = WorkloadSet { items: Vec::new() };
        assert!(empty.mixed(2, &mut Pcg::seeded(1)).is_err());
        assert!(empty.shared_prefix(2, 1, &mut Pcg::seeded(1)).is_err());
    }

    #[test]
    fn mixed_covers_all_tasks() {
        let ws = WorkloadSet::from_json(&sample_json()).unwrap();
        let m = ws.mixed(10, &mut Pcg::seeded(1)).unwrap();
        for t in TASKS {
            assert!(m.iter().any(|i| i.task == t), "missing {t}");
        }
    }

    #[test]
    fn templates_are_exactly_the_shared_prefix_prefixes() {
        let ws = WorkloadSet::from_json(&sample_json()).unwrap();
        let templates = ws.templates(2).unwrap();
        assert_eq!(templates.len(), TASKS.len());
        let items = ws.shared_prefix(10, 2, &mut Pcg::seeded(9)).unwrap();
        for it in &items {
            let (_, tpl) = templates
                .iter()
                .find(|(task, _)| *task == it.task)
                .expect("template for every task");
            assert!(
                it.prompt_ids.starts_with(tpl),
                "warm-up template must be the exact served prefix"
            );
        }
        // Unknown-task plumbing matches the rest of the set's error style.
        let empty = WorkloadSet { items: Vec::new() };
        assert!(empty.templates(2).is_err());
    }

    #[test]
    fn shared_prefix_items_share_their_family_template() {
        let ws = WorkloadSet::from_json(&sample_json()).unwrap();
        let items = ws.shared_prefix(10, 2, &mut Pcg::seeded(3)).unwrap();
        assert_eq!(items.len(), 10);
        for (i, it) in items.iter().enumerate() {
            assert_eq!(it.task, TASKS[i % TASKS.len()], "round-robin task order");
            let template: Vec<i32> = ws.task_items(&it.task)[0]
                .prompt_ids
                .iter()
                .copied()
                .take(2)
                .collect();
            assert!(
                it.prompt_ids.starts_with(&template),
                "item {i} does not share its family template"
            );
            assert!(it.prompt_ids.len() > template.len(), "body appended");
            // exactly one leading <bos>: the sampled item's was stripped
            assert_eq!(it.prompt_ids.iter().filter(|&&t| t == 1).count(), 1);
        }
        // same seed, same batch
        let again = ws.shared_prefix(10, 2, &mut Pcg::seeded(3)).unwrap();
        let a: Vec<_> = items.iter().map(|i| i.prompt_ids.clone()).collect();
        let b: Vec<_> = again.iter().map(|i| i.prompt_ids.clone()).collect();
        assert_eq!(a, b);
    }

    #[test]
    fn poisson_arrivals_monotone_with_correct_mean() {
        let mut rng = Pcg::seeded(2);
        let tr = ArrivalTrace::poisson(4000, 8.0, &mut rng);
        assert!(tr.arrivals.windows(2).all(|w| w[0].0 <= w[1].0));
        let mean_gap = tr.duration() / 4000.0;
        assert!((mean_gap - 0.125).abs() < 0.01, "mean gap {mean_gap}");
    }
}

//! Speculative-decoding core: drafting strategies (prompt-lookup, pruned
//! model, vanilla), the lossless rejection sampler (paper Eq. 2–3), and the
//! n-gram index substrate.

pub mod drafter;
pub mod ngram;
pub mod pruned;
pub mod sampler;

pub use drafter::{DraftCost, Drafter, NgramConfig, NgramDrafter, VanillaDrafter};
pub use ngram::NgramIndex;
pub use pruned::PrunedDrafter;
pub use sampler::{
    argmax, sample_logits, softmax_t, truncate_at_eos, verify_draft, Draft, VerifyOutcome,
};

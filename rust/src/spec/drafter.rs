//! Drafting strategies. The paper's methods map to:
//!
//! * `VanillaDrafter`  — never drafts (autoregressive baseline),
//! * `NgramDrafter`    — prompt-lookup decoding (the "Ngram" baseline *and*
//!   Quasar's drafter; Quasar changes only the verifier variant),
//! * `PrunedDrafter`   — layer-dropped model drafting (Table 5 ablation;
//!   `spec/pruned.rs`).
//!
//! One drafter instance per request: it tracks the request's committed
//! context and adapts its speculation depth from observed acceptance.

use super::ngram::NgramIndex;
use super::sampler::Draft;

/// Per-step model-call counts a drafter incurs (the Table-5 drafters cost
/// real forward passes; the n-gram drafter costs none). Feeds perfmodel.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct DraftCost {
    pub prefill_calls: u64,
    pub decode_calls: u64,
    pub lookup_tokens: u64,
}

impl DraftCost {
    pub fn merge(&mut self, o: &DraftCost) {
        self.prefill_calls += o.prefill_calls;
        self.decode_calls += o.decode_calls;
        self.lookup_tokens += o.lookup_tokens;
    }
}

/// A drafting strategy bound to one request's lifetime.
pub trait Drafter {
    /// Reset state for a fresh request with the given prompt.
    fn begin(&mut self, prompt: &[i32]) -> anyhow::Result<()>;

    /// Propose up to `gamma` tokens continuing the committed context.
    fn draft(&mut self, gamma: usize, temp: f64) -> anyhow::Result<Draft>;

    /// Tokens the engine committed this step (accepted prefix + bonus).
    fn observe_commit(&mut self, tokens: &[i32]) -> anyhow::Result<()>;

    /// Outcome feedback for adaptive speculation depth.
    fn observe_outcome(&mut self, drafted: usize, accepted: usize);

    /// Seed this drafter's intra-request depth state from a cross-request
    /// prior (the per-class controller's accepted-per-draft EWMA,
    /// `coordinator::gamma`). Called once per request, after [`begin`]
    /// (which resets to the cold-start constant — the fallback for classes
    /// with no history). Default: no-op for depth-less drafters.
    ///
    /// [`begin`]: Drafter::begin
    fn seed_depth_prior(&mut self, _prior: f64) {}

    /// Model calls consumed since the last call to this method.
    fn take_cost(&mut self) -> DraftCost;

    fn name(&self) -> &'static str;
}

/// Autoregressive baseline: no speculation.
#[derive(Debug, Default)]
pub struct VanillaDrafter;

impl Drafter for VanillaDrafter {
    fn begin(&mut self, _prompt: &[i32]) -> anyhow::Result<()> {
        Ok(())
    }

    fn draft(&mut self, _gamma: usize, _temp: f64) -> anyhow::Result<Draft> {
        Ok(Draft::empty())
    }

    fn observe_commit(&mut self, _tokens: &[i32]) -> anyhow::Result<()> {
        Ok(())
    }

    fn observe_outcome(&mut self, _d: usize, _a: usize) {}

    fn take_cost(&mut self) -> DraftCost {
        DraftCost::default()
    }

    fn name(&self) -> &'static str {
        "vanilla"
    }
}

/// Configuration for prompt-lookup drafting.
#[derive(Debug, Clone, Copy)]
pub struct NgramConfig {
    /// Lookup n-gram length range (paper: dynamically adjusted in [1, 4]).
    pub k_min: usize,
    pub k_max: usize,
    /// Speculation depth cap (tokens per draft).
    pub gamma: usize,
    /// Adapt effective gamma from an acceptance EWMA (the paper's
    /// "dynamically adjusted" lookup; disable for the Table-3 fixed sweep).
    pub adaptive: bool,
}

impl Default for NgramConfig {
    fn default() -> Self {
        NgramConfig { k_min: 1, k_max: 4, gamma: 5, adaptive: true }
    }
}

/// Prompt-lookup decoding (PLD): copy the continuation of the most recent
/// matching n-gram from the request's own context.
pub struct NgramDrafter {
    cfg: NgramConfig,
    index: NgramIndex,
    /// EWMA of accepted-per-draft, drives adaptive depth.
    accept_ewma: f64,
    cost: DraftCost,
}

impl NgramDrafter {
    pub fn new(cfg: NgramConfig) -> Self {
        NgramDrafter {
            cfg,
            index: NgramIndex::new(cfg.k_min, cfg.k_max),
            accept_ewma: cfg.gamma as f64 * 0.5,
            cost: DraftCost::default(),
        }
    }

    /// Effective speculation depth this step. A zero cap (no KV room, or
    /// `gamma: 0`) yields zero: the early return keeps the adaptive clamp
    /// below well-formed — `clamp(1, 0)` asserts `min <= max` and panics.
    fn effective_gamma(&self, cap: usize) -> usize {
        let cap = self.cfg.gamma.min(cap);
        if cap == 0 || !self.cfg.adaptive {
            return cap;
        }
        // Speculate a little past the recent acceptance level: deep enough
        // to capture streaks, shallow enough to bound wasted verification.
        let g = (self.accept_ewma + 2.0).round() as usize;
        g.clamp(1, cap)
    }
}

impl Drafter for NgramDrafter {
    fn begin(&mut self, prompt: &[i32]) -> anyhow::Result<()> {
        self.index = NgramIndex::new(self.cfg.k_min, self.cfg.k_max);
        self.index.extend(prompt);
        // Cold-start constant — the fallback when the request's class has
        // no cross-request history; the engine overrides it right after
        // via `seed_depth_prior` when the class controller has a prior.
        self.accept_ewma = self.cfg.gamma as f64 * 0.5;
        Ok(())
    }

    fn draft(&mut self, gamma: usize, _temp: f64) -> anyhow::Result<Draft> {
        let g = self.effective_gamma(gamma);
        let toks = self.index.draft(g, self.cfg.k_min, self.cfg.k_max);
        self.cost.lookup_tokens += toks.len() as u64;
        Ok(Draft::point_mass(toks))
    }

    fn observe_commit(&mut self, tokens: &[i32]) -> anyhow::Result<()> {
        self.index.extend(tokens);
        Ok(())
    }

    fn observe_outcome(&mut self, drafted: usize, accepted: usize) {
        if drafted > 0 {
            self.accept_ewma = 0.8 * self.accept_ewma + 0.2 * accepted as f64;
        }
    }

    fn seed_depth_prior(&mut self, prior: f64) {
        self.accept_ewma = prior;
    }

    fn take_cost(&mut self) -> DraftCost {
        std::mem::take(&mut self.cost)
    }

    fn name(&self) -> &'static str {
        "ngram"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vanilla_never_drafts() {
        let mut d = VanillaDrafter;
        d.begin(&[1, 2, 3]).unwrap();
        assert!(d.draft(8, 0.0).unwrap().is_empty());
        assert_eq!(d.name(), "vanilla");
    }

    #[test]
    fn ngram_drafts_from_prompt_repetition() {
        let mut d = NgramDrafter::new(NgramConfig { adaptive: false, gamma: 4, ..Default::default() });
        d.begin(&[7, 8, 9, 1, 2, 7, 8]).unwrap();
        let draft = d.draft(4, 0.0).unwrap();
        assert_eq!(draft.tokens, vec![9, 1, 2, 7]);
        assert!(draft.q_rows.is_none(), "PLD drafts are point-mass");
    }

    #[test]
    fn commit_extends_lookup_context() {
        let mut d = NgramDrafter::new(NgramConfig { adaptive: false, ..Default::default() });
        d.begin(&[1, 2, 3]).unwrap();
        assert!(d.draft(4, 0.0).unwrap().is_empty());
        d.observe_commit(&[4, 1, 2]).unwrap();
        // context ... 1 2 3 4 1 2 -> suffix [1,2] continues with 3
        assert_eq!(d.draft(2, 0.0).unwrap().tokens, vec![3, 4]);
    }

    #[test]
    fn adaptive_gamma_shrinks_on_rejection() {
        let mut d = NgramDrafter::new(NgramConfig { gamma: 8, adaptive: true, ..Default::default() });
        let ctx: Vec<i32> = std::iter::repeat([5, 6]).take(12).flatten().collect();
        d.begin(&ctx).unwrap();
        let g0 = d.draft(8, 0.0).unwrap().tokens.len();
        for _ in 0..20 {
            d.observe_outcome(4, 0); // everything rejected
        }
        let g1 = d.draft(8, 0.0).unwrap().tokens.len();
        assert!(g1 < g0, "gamma should shrink: {g0} -> {g1}");
        assert_eq!(g1, 2, "floor at ewma~0 + 2");
        for _ in 0..30 {
            d.observe_outcome(8, 8);
        }
        let g2 = d.draft(8, 0.0).unwrap().tokens.len();
        assert!(g2 >= 7, "gamma should recover, got {g2}");
    }

    #[test]
    fn zero_gamma_cap_is_an_empty_draft_not_a_panic() {
        // Regression: `clamp(1, 0)` asserts min <= max, so an adaptive
        // drafter handed cap 0 (a row with no KV room) used to panic.
        let mut d = NgramDrafter::new(NgramConfig { gamma: 8, adaptive: true, ..Default::default() });
        d.begin(&[5, 6, 5, 6, 5, 6]).unwrap();
        assert!(d.draft(0, 0.0).unwrap().is_empty());
        // Same reachable panic with `gamma: 0` configured and any cap.
        let mut d0 = NgramDrafter::new(NgramConfig { gamma: 0, adaptive: true, ..Default::default() });
        d0.begin(&[5, 6, 5, 6, 5, 6]).unwrap();
        assert!(d0.draft(4, 0.0).unwrap().is_empty());
    }

    #[test]
    fn seeded_prior_sets_first_step_depth() {
        // A second-turn request whose class learned a low acceptance must
        // draft shallow on its *first* step, not relearn from gamma/2.
        let mut d = NgramDrafter::new(NgramConfig { gamma: 8, adaptive: true, ..Default::default() });
        let ctx: Vec<i32> = std::iter::repeat([5, 6]).take(12).flatten().collect();
        d.begin(&ctx).unwrap();
        d.seed_depth_prior(0.0);
        assert_eq!(d.draft(8, 0.0).unwrap().tokens.len(), 2, "ewma 0 + 2");
        // ... and a high prior drafts deep immediately.
        d.begin(&ctx).unwrap();
        d.seed_depth_prior(8.0);
        assert_eq!(d.draft(8, 0.0).unwrap().tokens.len(), 8);
    }

    #[test]
    fn gamma_cap_respected() {
        let mut d = NgramDrafter::new(NgramConfig { gamma: 8, adaptive: false, ..Default::default() });
        d.begin(&[5, 6, 1, 2, 3, 4, 5, 6, 7, 8, 9, 5, 6]).unwrap();
        assert!(d.draft(3, 0.0).unwrap().tokens.len() <= 3);
    }

    #[test]
    fn cost_accumulates_and_resets() {
        let mut d = NgramDrafter::new(NgramConfig { adaptive: false, ..Default::default() });
        d.begin(&[7, 8, 9, 7, 8]).unwrap();
        let n = d.draft(4, 0.0).unwrap().tokens.len() as u64;
        assert!(n > 0);
        assert_eq!(d.take_cost().lookup_tokens, n);
        assert_eq!(d.take_cost(), DraftCost::default());
    }
}

//! Prompt-lookup n-gram index (PLD — Somasundaram et al. 2025), the paper's
//! self-speculative drafting mechanism ("Ngram" baseline and Quasar both use
//! it; only the verifier differs).
//!
//! The index maps every k-gram (k in `[k_min, k_max]`) of the growing
//! context to its *latest* end position, so a draft lookup is O(k_max) hash
//! probes instead of an O(n·k) backward scan. `push` is amortized O(k_max)
//! per appended token — the drafter stays negligible next to a model call,
//! which is exactly the regime the paper's speedup model assumes
//! (`drafter_cost_per_token_s` ~ 1 us).

use std::collections::HashMap;

/// Incremental n-gram index over a token stream.
#[derive(Debug, Clone)]
pub struct NgramIndex {
    k_min: usize,
    k_max: usize,
    tokens: Vec<i32>,
    /// (k, hash of k-gram ending at i) -> i (earliest occurrence wins:
    /// copying from the *first* occurrence yields the longest continuation,
    /// matching huggingface's prompt-lookup reference behaviour)
    table: HashMap<(u8, u64), usize>,
}

impl NgramIndex {
    pub fn new(k_min: usize, k_max: usize) -> Self {
        assert!(k_min >= 1 && k_min <= k_max && k_max <= 16);
        NgramIndex { k_min, k_max, tokens: Vec::new(), table: HashMap::new() }
    }

    pub fn len(&self) -> usize {
        self.tokens.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tokens.is_empty()
    }

    pub fn tokens(&self) -> &[i32] {
        &self.tokens
    }

    pub fn k_range(&self) -> (usize, usize) {
        (self.k_min, self.k_max)
    }

    fn gram_hash(gram: &[i32]) -> u64 {
        // FNV-1a over the token bytes; collisions are verified by direct
        // comparison in `lookup` so a collision costs a re-probe, never a
        // wrong draft.
        let mut h = 0xcbf29ce484222325u64;
        for t in gram {
            for b in t.to_le_bytes() {
                h ^= b as u64;
                h = h.wrapping_mul(0x100000001b3);
            }
        }
        h
    }

    /// Append one token, registering the k-grams that now end at the tail.
    pub fn push(&mut self, tok: i32) {
        self.tokens.push(tok);
        let n = self.tokens.len();
        for k in self.k_min..=self.k_max {
            if n >= k {
                let gram = &self.tokens[n - k..];
                self.table
                    .entry((k as u8, Self::gram_hash(gram)))
                    .or_insert(n);
            }
        }
    }

    pub fn extend(&mut self, toks: &[i32]) {
        for &t in toks {
            self.push(t);
        }
    }

    /// Prompt lookup: find the longest k-gram suffix (k from `k_hi` down to
    /// `k_lo`, clamped to the index range) that re-occurs *earlier* in the
    /// context, and copy up to `gamma` continuation tokens as the draft.
    pub fn draft(&self, gamma: usize, k_lo: usize, k_hi: usize) -> Vec<i32> {
        let n = self.tokens.len();
        let k_lo = k_lo.max(self.k_min);
        let k_hi = k_hi.min(self.k_max);
        if gamma == 0 || n == 0 {
            return Vec::new();
        }
        for k in (k_lo..=k_hi).rev() {
            if n < k + 1 {
                continue;
            }
            let suffix = &self.tokens[n - k..];
            if let Some(&end) = self.table.get(&(k as u8, Self::gram_hash(suffix))) {
                // `end` is the earliest end position of this k-gram; a match
                // at the very tail (end == n) is the suffix itself — not
                // useful. Verify against FNV collisions.
                if let Some(cont_start) = self.verified_match(suffix, end, n) {
                    let stop = (cont_start + gamma).min(n);
                    if cont_start < n {
                        return self.tokens[cont_start..stop].to_vec();
                    }
                }
            }
        }
        Vec::new()
    }

    /// Verify the hashed hit (guarding FNV collisions) and fall back to a
    /// forward scan on collision or tail-only occurrence.
    fn verified_match(&self, suffix: &[i32], end: usize, n: usize) -> Option<usize> {
        let k = suffix.len();
        let matches_at = |e: usize| &self.tokens[e - k..e] == suffix;
        if end < n && matches_at(end) {
            return Some(end);
        }
        // Hash collision or the earliest occurrence is the suffix itself:
        // scan forward for the first true occurrence before the tail
        // (bounded: contexts are <= max_seq so this stays cheap).
        for e in k..n {
            if matches_at(e) {
                return Some(e);
            }
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn idx(tokens: &[i32]) -> NgramIndex {
        let mut ix = NgramIndex::new(1, 4);
        ix.extend(tokens);
        ix
    }

    #[test]
    fn draft_copies_continuation_of_repeated_gram() {
        // ... [5 6 7 8] ... then suffix [5 6] -> continuation [7 8]
        let ix = idx(&[1, 5, 6, 7, 8, 2, 3, 5, 6]);
        let d = ix.draft(4, 1, 4);
        assert_eq!(d, vec![7, 8, 2, 3]);
    }

    #[test]
    fn longest_k_wins() {
        // suffix [6 7] matches continuation 9; suffix [7] alone matches 8
        let ix = idx(&[6, 7, 9, 4, 7, 8, 6, 7]);
        assert_eq!(ix.draft(1, 1, 4), vec![9]); // 2-gram beats 1-gram
        // restricted to k=1 -> earliest occurrence of [7] (index 1) -> 9
        assert_eq!(ix.draft(1, 1, 1), vec![9]);
    }

    #[test]
    fn no_match_returns_empty() {
        let ix = idx(&[1, 2, 3, 4, 5]);
        assert!(ix.draft(4, 1, 4).is_empty());
        let empty = NgramIndex::new(1, 4);
        assert!(empty.draft(4, 1, 4).is_empty());
    }

    #[test]
    fn gamma_caps_draft_length() {
        let ix = idx(&[5, 6, 1, 2, 3, 4, 9, 5, 6]);
        assert_eq!(ix.draft(2, 1, 4), vec![1, 2]);
        assert_eq!(ix.draft(0, 1, 4), Vec::<i32>::new());
    }

    #[test]
    fn draft_never_exceeds_context() {
        let ix = idx(&[5, 6, 7, 5, 6]);
        // continuation after earlier [5,6] is [7,5,6] then context ends
        assert_eq!(ix.draft(10, 1, 4), vec![7, 5, 6]);
    }

    #[test]
    fn incremental_equals_batch() {
        let toks = [3, 1, 4, 1, 5, 9, 2, 6, 5, 3, 5, 1, 4];
        let mut a = NgramIndex::new(1, 3);
        for &t in &toks {
            a.push(t);
        }
        let b = {
            let mut ix = NgramIndex::new(1, 3);
            ix.extend(&toks);
            ix
        };
        assert_eq!(a.draft(4, 1, 3), b.draft(4, 1, 3));
        assert_eq!(a.len(), toks.len());
    }

    #[test]
    fn self_match_at_tail_is_skipped() {
        // the only occurrence of the suffix is the suffix itself
        let ix = idx(&[1, 2, 3]);
        assert!(ix.draft(4, 2, 4).is_empty());
    }

    #[test]
    fn repeated_pattern_heavy_context_drafts_long() {
        // templated GSM8K-style context: high draftability
        let mut toks = Vec::new();
        for _ in 0..6 {
            toks.extend_from_slice(&[10, 11, 12, 13, 14, 15]);
        }
        // suffix matches the first template instance; the continuation is
        // the whole next instance
        let ix = idx(&toks);
        let d = ix.draft(6, 1, 4);
        assert_eq!(d.len(), 6);
        assert_eq!(d, vec![10, 11, 12, 13, 14, 15]);
    }
}

//! Sampling and lossless rejection-sampling verification (paper §3.1,
//! Eq. 2–3).
//!
//! Two draft-distribution regimes:
//!  * **point-mass drafts** (prompt-lookup copies): `q(x) = δ(x = draft)`,
//!    so Eq. 2 reduces to accept-with-probability `p(draft)` under sampling
//!    and to argmax equality under greedy decoding, and the corrective
//!    resample distribution `norm(max(0, p - q))` is `p` with the draft
//!    token zeroed;
//!  * **model drafts** (pruned drafter, Table 5): the full `q` row is
//!    supplied and Eq. 2/3 are applied verbatim.
//!
//! Temperature semantics follow the paper's T=0/T=1 settings: `T = 0` is
//! greedy (deterministic argmax at every position), `T > 0` scales logits
//! before the softmax.

use crate::util::rng::Pcg;

/// Numerically-stable softmax with temperature into `out`.
pub fn softmax_t(logits: &[f32], temp: f64, out: &mut Vec<f32>) {
    out.clear();
    out.reserve(logits.len());
    let t = temp.max(1e-6) as f32;
    let mut mx = f32::NEG_INFINITY;
    for &l in logits {
        mx = mx.max(l / t);
    }
    let mut sum = 0.0f32;
    for &l in logits {
        let e = ((l / t) - mx).exp();
        out.push(e);
        sum += e;
    }
    let inv = 1.0 / sum;
    for v in out.iter_mut() {
        *v *= inv;
    }
}

pub fn argmax(row: &[f32]) -> usize {
    let mut best = 0;
    let mut bv = f32::NEG_INFINITY;
    for (i, &v) in row.iter().enumerate() {
        if v > bv {
            bv = v;
            best = i;
        }
    }
    best
}

/// Sample an index from a probability row.
pub fn sample_probs(probs: &[f32], rng: &mut Pcg) -> usize {
    let r = rng.f64() as f32;
    let mut acc = 0.0f32;
    for (i, &p) in probs.iter().enumerate() {
        acc += p;
        if r < acc {
            return i;
        }
    }
    probs.len() - 1
}

/// Sample from logits at temperature (`T = 0` -> argmax).
pub fn sample_logits(logits: &[f32], temp: f64, rng: &mut Pcg) -> i32 {
    if temp <= 0.0 {
        return argmax(logits) as i32;
    }
    let mut probs = Vec::new();
    softmax_t(logits, temp, &mut probs);
    sample_probs(&probs, rng) as i32
}

/// A drafter's proposal for one request step.
#[derive(Debug, Clone, Default)]
pub struct Draft {
    pub tokens: Vec<i32>,
    /// Full draft distribution rows (aligned with `tokens`); `None` for
    /// point-mass (copy) drafts.
    pub q_rows: Option<Vec<Vec<f32>>>,
}

impl Draft {
    pub fn empty() -> Self {
        Draft::default()
    }

    pub fn point_mass(tokens: Vec<i32>) -> Self {
        Draft { tokens, q_rows: None }
    }

    pub fn len(&self) -> usize {
        self.tokens.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tokens.is_empty()
    }
}

/// Outcome of verifying one draft against target logits.
#[derive(Debug, Clone, PartialEq)]
pub struct VerifyOutcome {
    /// Number of draft tokens accepted (prefix length).
    pub accepted: usize,
    /// The bonus (all accepted) or corrective (first rejection) token —
    /// always emitted, so a step always commits `accepted + 1` tokens.
    pub next_token: i32,
}

/// Verify a draft against the verifier's logits rows.
///
/// `logit_rows(i)` must yield the logits conditioned on the context plus
/// `draft.tokens[..i]` — i.e. row `i` scores `draft.tokens[i]` — and be
/// valid for `i` in `0..=draft.len()`.
pub fn verify_draft<'a, F>(
    draft: &Draft,
    logit_rows: F,
    temp: f64,
    rng: &mut Pcg,
) -> VerifyOutcome
where
    F: Fn(usize) -> &'a [f32],
{
    let g = draft.len();
    if temp <= 0.0 {
        // Greedy: accept while the draft matches argmax.
        for i in 0..g {
            let top = argmax(logit_rows(i)) as i32;
            if top != draft.tokens[i] {
                return VerifyOutcome { accepted: i, next_token: top };
            }
        }
        return VerifyOutcome { accepted: g, next_token: argmax(logit_rows(g)) as i32 };
    }

    let mut p = Vec::new();
    for i in 0..g {
        softmax_t(logit_rows(i), temp, &mut p);
        let x = draft.tokens[i] as usize;
        let px = p.get(x).copied().unwrap_or(0.0) as f64;
        let qx = match &draft.q_rows {
            None => 1.0, // point-mass draft
            Some(rows) => rows[i].get(x).copied().unwrap_or(0.0) as f64,
        };
        let accept_p = if qx <= 0.0 { 1.0 } else { (px / qx).min(1.0) };
        if rng.f64() < accept_p {
            continue;
        }
        // Rejected: corrective resample from norm(max(0, p - q)) (Eq. 3).
        let next = match &draft.q_rows {
            None => {
                // q is a point mass at x: residual is p with x zeroed.
                let mut resid = p.clone();
                resid[x] = 0.0;
                renorm_sample(&resid, &p, rng)
            }
            Some(rows) => {
                let resid: Vec<f32> = p
                    .iter()
                    .zip(&rows[i])
                    .map(|(&pv, &qv)| (pv - qv).max(0.0))
                    .collect();
                renorm_sample(&resid, &p, rng)
            }
        };
        return VerifyOutcome { accepted: i, next_token: next };
    }
    // All accepted: bonus token from the last row.
    let mut probs = Vec::new();
    softmax_t(logit_rows(g), temp, &mut probs);
    VerifyOutcome { accepted: g, next_token: sample_probs(&probs, rng) as i32 }
}

/// Cut a committed-token block at the tokenizer-contract `<eos>`, keeping
/// it. The shared finish rule for the engine's commit path and any replay
/// of committed streams — token ids come from [`crate::tokenizer::EOS_ID`]
/// rather than a re-hardcoded literal.
pub fn truncate_at_eos(tokens: &mut Vec<i32>) {
    if let Some(e) = tokens.iter().position(|&t| t == crate::tokenizer::EOS_ID) {
        tokens.truncate(e + 1);
    }
}

/// Sample from an unnormalized residual distribution, falling back to the
/// verifier's own row `p` when the residual carries no mass.
fn renorm_sample(resid: &[f32], p: &[f32], rng: &mut Pcg) -> i32 {
    let sum: f32 = resid.iter().sum();
    if sum <= 0.0 {
        // Degenerate residual: q >= p at every token within f32, which is
        // exactly the q ≈ p regime a well-calibrated (e.g. quantized)
        // drafter produces. Eq. 3's corrective distribution carries no
        // mass, so the lossless fallback is the verifier's own row p.
        // (The old code took argmax of the all-zero residual and silently
        // emitted token 0 every time.)
        return sample_probs(p, rng) as i32;
    }
    let r = rng.f64() as f32 * sum;
    let mut acc = 0.0f32;
    for (i, &v) in resid.iter().enumerate() {
        acc += v;
        if r < acc {
            return i as i32;
        }
    }
    (resid.len() - 1) as i32
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rows(data: Vec<Vec<f32>>) -> impl Fn(usize) -> &'static [f32] {
        let leaked: &'static Vec<Vec<f32>> = Box::leak(Box::new(data));
        move |i| leaked[i].as_slice()
    }

    #[test]
    fn truncate_at_eos_keeps_eos_and_ignores_rest() {
        let eos = crate::tokenizer::EOS_ID;
        let mut v = vec![5, 6, eos, 7, 8];
        truncate_at_eos(&mut v);
        assert_eq!(v, vec![5, 6, eos]);
        let mut no_eos = vec![5, 6, 7];
        truncate_at_eos(&mut no_eos);
        assert_eq!(no_eos, vec![5, 6, 7]);
        let mut empty: Vec<i32> = Vec::new();
        truncate_at_eos(&mut empty);
        assert!(empty.is_empty());
    }

    #[test]
    fn softmax_sums_to_one_and_orders() {
        let mut p = Vec::new();
        softmax_t(&[1.0, 2.0, 3.0], 1.0, &mut p);
        assert!((p.iter().sum::<f32>() - 1.0).abs() < 1e-6);
        assert!(p[2] > p[1] && p[1] > p[0]);
        // low temperature sharpens
        let mut p_cold = Vec::new();
        softmax_t(&[1.0, 2.0, 3.0], 0.1, &mut p_cold);
        assert!(p_cold[2] > p[2]);
    }

    #[test]
    fn greedy_accepts_matching_prefix() {
        // rows argmax: 1, 2, 0 — draft [1, 2, 2] accepts 2 then corrects to 0
        let f = rows(vec![
            vec![0.0, 5.0, 0.0],
            vec![0.0, 0.0, 5.0],
            vec![9.0, 0.0, 0.0],
            vec![0.0, 0.0, 0.0],
        ]);
        let d = Draft::point_mass(vec![1, 2, 2]);
        let out = verify_draft(&d, f, 0.0, &mut Pcg::seeded(1));
        assert_eq!(out, VerifyOutcome { accepted: 2, next_token: 0 });
    }

    #[test]
    fn greedy_all_accepted_emits_bonus() {
        let f = rows(vec![vec![0.0, 5.0], vec![5.0, 0.0], vec![0.0, 7.0]]);
        let d = Draft::point_mass(vec![1, 0]);
        let out = verify_draft(&d, f, 0.0, &mut Pcg::seeded(1));
        assert_eq!(out, VerifyOutcome { accepted: 2, next_token: 1 });
    }

    #[test]
    fn empty_draft_is_plain_decode() {
        let f = rows(vec![vec![0.0, 0.0, 3.0]]);
        let out = verify_draft(&Draft::empty(), f, 0.0, &mut Pcg::seeded(1));
        assert_eq!(out, VerifyOutcome { accepted: 0, next_token: 2 });
    }

    #[test]
    fn point_mass_acceptance_rate_tracks_p() {
        // p(draft token) ~= 0.731 at T=1 for logits [0, 1]
        let f = rows(vec![vec![0.0, 1.0], vec![0.0, 0.0]]);
        let d = Draft::point_mass(vec![1]);
        let mut rng = Pcg::seeded(99);
        let n = 20_000;
        let mut acc = 0usize;
        for _ in 0..n {
            let out = verify_draft(&d, &f, 1.0, &mut rng);
            acc += out.accepted;
        }
        let rate = acc as f64 / n as f64;
        let expect = (1.0f64).exp() / (1.0 + (1.0f64).exp()); // sigmoid(1)
        assert!((rate - expect).abs() < 0.01, "rate {rate} vs {expect}");
    }

    #[test]
    fn rejection_resample_never_returns_rejected_point_mass_token() {
        // Make p(draft)=tiny so rejection is near-certain; the corrective
        // token must never equal the rejected draft token.
        let f = rows(vec![vec![5.0, -10.0, 4.0], vec![0.0; 3]]);
        let d = Draft::point_mass(vec![1]);
        let mut rng = Pcg::seeded(7);
        for _ in 0..2000 {
            let out = verify_draft(&d, &f, 1.0, &mut rng);
            if out.accepted == 0 {
                assert_ne!(out.next_token, 1);
            }
        }
    }

    #[test]
    fn model_draft_lossless_distribution() {
        // With q == p exactly, acceptance probability is 1 for every token.
        let logits = vec![vec![0.3f32, 1.2, -0.5], vec![0.0, 0.0, 0.0]];
        let mut q = Vec::new();
        softmax_t(&logits[0], 1.0, &mut q);
        let f = rows(logits.clone());
        let mut rng = Pcg::seeded(3);
        for tok in 0..3 {
            let d = Draft { tokens: vec![tok], q_rows: Some(vec![q.clone()]) };
            let out = verify_draft(&d, &f, 1.0, &mut rng);
            assert_eq!(out.accepted, 1, "token {tok} should always accept");
        }
    }

    #[test]
    fn degenerate_residual_falls_back_to_verifier_row_not_token_zero() {
        // Regression: q >= p at every token (q ≈ p, the quantized-draft
        // regime) makes Eq. 3's residual identically zero on every
        // rejection. The old fallback took argmax of the all-zero residual
        // and always emitted token 0; the corrective token must instead be
        // sampled from the verifier's own row p.
        let logits = vec![vec![-10.0f32, 1.0, 0.0], vec![0.0; 3]];
        let mut p = Vec::new();
        softmax_t(&logits[0], 1.0, &mut p);
        // q = 1.25 * p: pointwise >= p (zero residual), accept prob 0.8
        let q: Vec<f32> = p.iter().map(|x| x * 1.25).collect();
        let f = rows(logits.clone());
        let d = Draft { tokens: vec![1], q_rows: Some(vec![q]) };
        let mut rng = Pcg::seeded(13);
        let mut rejected = 0usize;
        let mut seen = [0usize; 3];
        for _ in 0..4000 {
            let out = verify_draft(&d, &f, 1.0, &mut rng);
            if out.accepted == 0 {
                rejected += 1;
                seen[out.next_token as usize] += 1;
            }
        }
        assert!(rejected > 500, "q > p must reject ~20% of draws, got {rejected}");
        // p ~ [2e-5, 0.73, 0.27]: the fallback must cover p's support and
        // must not collapse onto token 0 (whose mass is negligible).
        assert!(seen[1] > 0 && seen[2] > 0, "fallback must sample from p: {seen:?}");
        assert!(
            seen[0] * 10 < rejected,
            "token 0 dominated the fallback (old argmax-of-zero bug): {seen:?}"
        );
        let frac1 = seen[1] as f64 / rejected as f64;
        assert!((frac1 - p[1] as f64).abs() < 0.05, "fallback should track p: {frac1}");
    }

    #[test]
    fn model_draft_overconfident_q_rejects() {
        // q puts mass 1.0 on a token with low p -> acceptance prob = p/q = p.
        let f = rows(vec![vec![2.0f32, -2.0], vec![0.0, 0.0]]);
        let mut q_row = vec![0.0f32, 1.0];
        let d = Draft { tokens: vec![1], q_rows: Some(vec![q_row.clone()]) };
        let mut rng = Pcg::seeded(11);
        let n = 10_000;
        let mut acc = 0;
        for _ in 0..n {
            acc += verify_draft(&d, &f, 1.0, &mut rng).accepted;
        }
        let mut p = Vec::new();
        softmax_t(&[2.0, -2.0], 1.0, &mut p);
        let rate = acc as f64 / n as f64;
        assert!((rate - p[1] as f64).abs() < 0.01, "rate {rate} vs p {}", p[1]);
        // and the corrective token is always 0 (the only positive residual)
        q_row[1] = 1.0;
        let out = loop {
            let o = verify_draft(&d, &f, 1.0, &mut rng);
            if o.accepted == 0 {
                break o;
            }
        };
        assert_eq!(out.next_token, 0);
    }

    #[test]
    fn sample_logits_greedy_vs_stochastic() {
        let mut rng = Pcg::seeded(5);
        assert_eq!(sample_logits(&[0.0, 9.0, 1.0], 0.0, &mut rng), 1);
        // stochastic still overwhelmingly picks the 9.0 logit
        let mut ones = 0;
        for _ in 0..1000 {
            if sample_logits(&[0.0, 9.0, 1.0], 1.0, &mut rng) == 1 {
                ones += 1;
            }
        }
        assert!(ones > 950);
    }
}

//! Structural-pruning drafter (Table 5 / §5 "The Failure of Training-Free
//! Pruning"): the first `keep`% of the target model's layers drafting
//! autoregressively, verified by the full-precision model.
//!
//! This drafter costs *real* forward passes (its own prefill + one decode
//! per drafted token), which is exactly the paper's point — a 90%-depth
//! drafter aligns well (high L) but its per-token cost erases the speedup
//! (0.80x), while a 50%-depth drafter is cheap but misaligned (L ~ 1.03).

use std::rc::Rc;

use anyhow::Result;

use crate::runtime::{ModelRuntime, Tensor};

use super::drafter::{DraftCost, Drafter};
use super::sampler::{sample_logits, softmax_t, Draft};

/// Layer-dropped model drafting against its own KV cache.
pub struct PrunedDrafter {
    model: Rc<ModelRuntime>,
    /// Artifact variant name: "pruned90" | "pruned75" | "pruned50".
    variant: String,
    n_layers: usize,
    k: Tensor<f32>,
    v: Tensor<f32>,
    committed: Vec<i32>,
    /// KV cache coverage: positions `0..cached` hold committed tokens.
    cached: usize,
    cost: DraftCost,
    rng: crate::util::rng::Pcg,
}

impl PrunedDrafter {
    pub fn new(model: Rc<ModelRuntime>, variant: &str, seed: u64) -> Result<Self> {
        let entry = model.entry.artifact(variant, "decode", 1)?;
        let n_layers = entry.n_layers;
        let (k, v) = model.empty_cache(n_layers, 1);
        Ok(PrunedDrafter {
            model,
            variant: variant.to_string(),
            n_layers,
            k,
            v,
            committed: Vec::new(),
            cached: 0,
            cost: DraftCost::default(),
            rng: crate::util::rng::Pcg::seeded(seed),
        })
    }

    /// Feed committed-but-uncached tokens so the drafter's cache catches up
    /// to `committed.len() - 1` (the newest token is fed by `draft` itself).
    fn catch_up(&mut self) -> Result<()> {
        while self.cached + 1 < self.committed.len() {
            let tok = self.committed[self.cached];
            let out = self.model.run_chunk(
                &self.variant, "decode", 1, &[tok], &self.k, &self.v,
                &[self.cached as i32],
            )?;
            self.cost.decode_calls += 1;
            self.k = out.k;
            self.v = out.v;
            self.cached += 1;
        }
        Ok(())
    }

    fn max_seq(&self) -> usize {
        self.model.cfg().max_seq
    }
}

impl Drafter for PrunedDrafter {
    fn begin(&mut self, prompt: &[i32]) -> Result<()> {
        let cfg = self.model.cfg().clone();
        let (k, v) = self.model.empty_cache(self.n_layers, 1);
        self.k = k;
        self.v = v;
        self.committed = prompt.to_vec();
        // Prefill the prompt except its last token (fed at first draft).
        let p = cfg.prefill_len;
        let feed = &prompt[..prompt.len().saturating_sub(1).min(p)];
        let mut toks = vec![0i32; p];
        toks[..feed.len()].copy_from_slice(feed);
        let out = self.model.run_chunk(
            &self.variant, "prefill", 1, &toks, &self.k, &self.v, &[0],
        )?;
        self.cost.prefill_calls += 1;
        self.k = out.k;
        self.v = out.v;
        self.cached = feed.len();
        Ok(())
    }

    fn draft(&mut self, gamma: usize, temp: f64) -> Result<Draft> {
        self.catch_up()?;
        // Nothing committed yet (empty prompt): no token to continue from.
        let Some(&seed_tok) = self.committed.last() else {
            return Ok(Draft::empty());
        };
        let mut tokens = Vec::with_capacity(gamma);
        let mut q_rows = Vec::with_capacity(gamma);
        let mut last = seed_tok;
        let mut pos = self.cached;
        // Speculative writes beyond `cached` are rolled back simply by not
        // advancing `cached`: the engine's next commit overwrites them (the
        // same stale-slot argument as the verifier cache, model.py header).
        let mut k = self.k.clone();
        let mut v = self.v.clone();
        for _ in 0..gamma {
            if pos + 2 >= self.max_seq() {
                break;
            }
            let out = self
                .model
                .run_chunk(&self.variant, "decode", 1, &[last], &k, &v, &[pos as i32])?;
            self.cost.decode_calls += 1;
            let row = out.logits.row(&[0, 0]);
            let tok = sample_logits(row, temp, &mut self.rng);
            let mut q = Vec::new();
            softmax_t(row, temp.max(1e-3), &mut q);
            tokens.push(tok);
            q_rows.push(q);
            k = out.k;
            v = out.v;
            pos += 1;
            last = tok;
        }
        // Keep the caches *without* advancing `cached`: only commits count.
        self.k = k;
        self.v = v;
        Ok(Draft { tokens, q_rows: Some(q_rows) })
    }

    fn observe_commit(&mut self, tokens: &[i32]) -> Result<()> {
        self.committed.extend_from_slice(tokens);
        Ok(())
    }

    fn observe_outcome(&mut self, _drafted: usize, _accepted: usize) {}

    fn take_cost(&mut self) -> DraftCost {
        std::mem::take(&mut self.cost)
    }

    fn name(&self) -> &'static str {
        "pruned"
    }
}

//! Shared harness for the paper-reproduction benchmarks (criterion is not
//! vendored offline; each `rust/benches/*.rs` is a `harness = false` binary
//! built on this module).
//!
//! A *method* is one of the paper's rows (vanilla / ngram / quasar /
//! draft-pruned*); `run_method` executes it on a prompt set with real
//! numerics, collects acceptance statistics and the call log, and prices the
//! log on the simulated device (perfmodel) to produce the paper-shape Speed
//! numbers. CPU wall-clock is reported alongside (DESIGN.md §9).

use std::path::PathBuf;
use std::rc::Rc;

use anyhow::{Context, Result};

use crate::coordinator::{DrafterKind, Engine, EngineConfig};
use crate::metrics::SpecStats;
use crate::perfmodel::PerfModel;
use crate::runtime::{Manifest, ModelRuntime, XlaRuntime};
use crate::tokenizer::Tokenizer;
use crate::util::rng::Pcg;
use crate::workload::{bench_params, WorkItem, WorkloadSet};

/// Everything a bench needs, loaded once.
pub struct BenchCtx {
    pub manifest: Manifest,
    pub rt: Rc<XlaRuntime>,
    pub tok: Tokenizer,
    pub workloads: WorkloadSet,
}

impl BenchCtx {
    /// Artifact root from `QUASAR_ARTIFACTS` (default `artifacts/`).
    pub fn load() -> Result<Self> {
        let root = std::env::var("QUASAR_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"));
        let manifest = Manifest::load(&root)
            .context("run `make artifacts` before benches")?;
        let rt = Rc::new(XlaRuntime::cpu()?);
        let tok = Tokenizer::load(&manifest.tokenizer_path)?;
        let workloads = WorkloadSet::load(&manifest.workloads_path)?;
        Ok(BenchCtx { manifest, rt, tok, workloads })
    }

    pub fn model(&self, name: &str) -> Result<Rc<ModelRuntime>> {
        Ok(Rc::new(ModelRuntime::load(
            Rc::clone(&self.rt),
            &self.manifest,
            name,
        )?))
    }

    pub fn perf(&self, model: &Rc<ModelRuntime>) -> PerfModel {
        PerfModel::new(self.manifest.cost_model.clone(), model.cfg().clone())
    }

    /// Bench scale knobs (env-overridable so CI and full runs share code).
    pub fn n_prompts(&self, default: usize) -> usize {
        std::env::var("QUASAR_BENCH_N")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn max_new(&self, default: usize) -> usize {
        std::env::var("QUASAR_BENCH_TOKENS")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }
}

/// Result of one (method, workload) run.
#[derive(Debug, Clone)]
pub struct MethodResult {
    pub method: String,
    pub stats: SpecStats,
    pub tokens_out: u64,
    /// Modeled decode-phase seconds on the simulated device.
    pub modeled_s: f64,
    /// Measured CPU seconds inside PJRT executions (decode phase).
    pub wall_s: f64,
    pub requests: usize,
}

impl MethodResult {
    pub fn mean_l(&self) -> f64 {
        self.stats.mean_acceptance_len()
    }

    /// Modeled tokens/second on the simulated device.
    pub fn modeled_tps(&self) -> f64 {
        self.tokens_out as f64 / self.modeled_s.max(1e-12)
    }

    /// Speedup vs a baseline run over the same workload.
    pub fn speedup_vs(&self, baseline: &MethodResult) -> f64 {
        self.modeled_tps() / baseline.modeled_tps().max(1e-12)
    }
}

/// Pruned drafter (artifact variant, depth) for perfmodel pricing, when the
/// method uses one — the drafter's calls are priced at its *own* variant's
/// bytes/weight, not fp32's.
fn pruned_pricing(mr: &Rc<ModelRuntime>, cfg: &EngineConfig) -> Option<(String, usize)> {
    match &cfg.drafter {
        DrafterKind::Pruned(v) => mr
            .entry
            .artifact(v, "decode", 1)
            .ok()
            .map(|a| (v.clone(), a.n_layers)),
        _ => None,
    }
}

/// Run one method over a prompt set, returning stats + priced times.
pub fn run_method(
    mr: &Rc<ModelRuntime>,
    perf: &PerfModel,
    cfg: EngineConfig,
    items: &[WorkItem],
    temp: f64,
    max_new: usize,
) -> Result<MethodResult> {
    let method = cfg.method_name();
    let pl = pruned_pricing(mr, &cfg);
    let mut engine = Engine::new(Rc::clone(mr), cfg)?;
    for it in items {
        engine.submit(it.prompt_ids.clone(), bench_params(temp, max_new), &it.task);
    }
    let done = engine.run_to_completion()?;
    let mut stats = SpecStats::default();
    let mut tokens = 0u64;
    for c in &done {
        stats.merge(&c.stats);
        tokens += c.tokens.len() as u64;
    }
    let log = &engine.call_log;
    let modeled_s = perf.decode_time(log, pl.as_ref().map(|(v, n)| (v.as_str(), *n)));
    let wall_s: f64 = log
        .records
        .iter()
        .filter(|r| r.fn_kind != crate::coordinator::FnKind::Prefill)
        .map(|r| r.wall_s)
        .sum();
    Ok(MethodResult {
        method,
        stats,
        tokens_out: tokens,
        modeled_s,
        wall_s,
        requests: done.len(),
    })
}

/// Deterministic per-(bench, task) prompt sample. Errors (rather than
/// panics) when the task has no exported items — a mistyped `--task` flag
/// should fail with the exported task list in the message.
pub fn prompts_for(ctx: &BenchCtx, task: &str, n: usize, seed: u64) -> Result<Vec<WorkItem>> {
    let mut rng = Pcg::seeded(seed ^ 0xBEEF);
    ctx.workloads.sample(task, n, &mut rng)
}

// ---------------------------------------------------------------------
// Machine-readable benchmark artifacts
// ---------------------------------------------------------------------

/// Flat JSON benchmark artifact, written as `BENCH_<scenario>.json` so CI
/// can upload run metrics (throughput, latency percentiles, cache and KV
/// residency counters, modeled savings) and diff them across runs. Shared
/// by `serve_benchmark --bench-json` and the artifact-free mock-sim bench.
pub struct BenchReport {
    scenario: String,
    fields: Vec<(String, crate::util::json::Json)>,
}

impl BenchReport {
    pub fn new(scenario: &str) -> Self {
        BenchReport {
            scenario: scenario.to_string(),
            fields: vec![(
                "scenario".to_string(),
                crate::util::json::Json::Str(scenario.to_string()),
            )],
        }
    }

    pub fn num(&mut self, name: &str, v: f64) -> &mut Self {
        self.fields
            .push((name.to_string(), crate::util::json::Json::Num(v)));
        self
    }

    pub fn text(&mut self, name: &str, v: &str) -> &mut Self {
        self.fields
            .push((name.to_string(), crate::util::json::Json::Str(v.to_string())));
        self
    }

    pub fn flag(&mut self, name: &str, v: bool) -> &mut Self {
        self.fields
            .push((name.to_string(), crate::util::json::Json::Bool(v)));
        self
    }

    /// Attach a pre-built JSON value (arrays, nested objects — e.g. the
    /// per-replica breakdown block).
    pub fn json(&mut self, name: &str, v: crate::util::json::Json) -> &mut Self {
        self.fields.push((name.to_string(), v));
        self
    }

    pub fn to_json(&self) -> crate::util::json::Json {
        crate::util::json::Json::Obj(self.fields.iter().cloned().collect())
    }

    /// Write `<dir>/BENCH_<scenario>.json` (creating `dir`), returning the
    /// path written.
    pub fn write_to(&self, dir: &std::path::Path) -> Result<PathBuf> {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating bench dir {dir:?}"))?;
        let path = dir.join(format!("BENCH_{}.json", self.scenario));
        std::fs::write(&path, self.to_json().to_string())
            .with_context(|| format!("writing {path:?}"))?;
        Ok(path)
    }
}

// ---------------------------------------------------------------------
// Table formatting
// ---------------------------------------------------------------------

/// Markdown-ish fixed-width table writer used by all benches so EXPERIMENTS.md
/// can embed the output verbatim.
pub struct TableWriter {
    pub title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl TableWriter {
    pub fn new(title: &str, header: &[&str]) -> Self {
        TableWriter {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "column count mismatch");
        self.rows.push(cells);
    }

    pub fn print(&self) {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        println!("\n### {}\n", self.title);
        let fmt_row = |cells: &[String]| {
            let body: Vec<String> = cells
                .iter()
                .zip(&widths)
                .map(|(c, w)| format!("{c:<w$}"))
                .collect();
            println!("| {} |", body.join(" | "));
        };
        fmt_row(&self.header);
        let sep: Vec<String> = widths.iter().map(|w| "-".repeat(*w)).collect();
        fmt_row(&sep);
        for row in &self.rows {
            fmt_row(row);
        }
    }
}

/// `1.23x` formatting used across tables.
pub fn speed(x: f64) -> String {
    format!("{x:.2}x")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_writer_formats() {
        let mut t = TableWriter::new("t", &["a", "bb"]);
        t.row(vec!["1".into(), "2".into()]);
        t.print(); // should not panic
        assert_eq!(speed(1.28394), "1.28x");
    }

    #[test]
    #[should_panic(expected = "column count mismatch")]
    fn table_writer_validates_columns() {
        let mut t = TableWriter::new("t", &["a"]);
        t.row(vec!["1".into(), "2".into()]);
    }

    #[test]
    fn bench_report_roundtrips_through_disk() {
        let dir = std::env::temp_dir()
            .join(format!("quasar_bench_report_{}", std::process::id()));
        let mut r = BenchReport::new("unit");
        r.num("throughput_tok_s", 123.5)
            .text("checksum", "00ff")
            .flag("paged_rows", true);
        let path = r.write_to(&dir).unwrap();
        assert_eq!(path.file_name().unwrap(), "BENCH_unit.json");
        let v = crate::util::json::parse_file(&path).unwrap();
        assert_eq!(v.get("scenario").unwrap().as_str().unwrap(), "unit");
        assert_eq!(
            v.get("throughput_tok_s").unwrap().as_f64().unwrap(),
            123.5
        );
        assert_eq!(v.get("checksum").unwrap().as_str().unwrap(), "00ff");
        assert!(v.get("paged_rows").unwrap().as_bool().unwrap());
        std::fs::remove_dir_all(&dir).ok();
    }
}

//! Quasar leader binary: `serve` a model over TCP, `generate` from a prompt
//! on the command line, or dump `info` about the artifact set.

use std::path::PathBuf;
use std::rc::Rc;

use anyhow::{bail, Result};
use quasar::coordinator::{ClusterConfig, ClusterHandle, DispatchPolicy, DrafterKind, Engine,
                          EngineConfig, GenParams, SchedPolicy};
use quasar::runtime::{Manifest, ModelRuntime, XlaRuntime};
use quasar::spec::NgramConfig;
use quasar::tokenizer::Tokenizer;
use quasar::util::cli::Cli;

fn main() {
    // PJRT init + HLO parsing need a big stack (util::bigstack docs).
    quasar::util::bigstack::run(|| {
        if let Err(e) = real_main() {
            eprintln!("error: {e:#}");
            std::process::exit(1);
        }
    })
}

fn drafter_kind(name: &str, gamma: usize) -> Result<DrafterKind> {
    Ok(match name {
        "vanilla" => DrafterKind::Vanilla,
        "ngram" => DrafterKind::Ngram(NgramConfig { gamma, ..Default::default() }),
        "pruned90" | "pruned75" | "pruned50" => DrafterKind::Pruned(name.to_string()),
        other => bail!("unknown drafter '{other}' (vanilla|ngram|pruned90|pruned75|pruned50)"),
    })
}

fn real_main() -> Result<()> {
    let parsed = Cli::new(
        "quasar",
        "Quantized self-speculative serving engine (paper reproduction).\n\
         Subcommands (first positional): serve | generate | info",
    )
    .opt("artifacts", Some("artifacts"), "artifact root (make artifacts)")
    .opt("model", Some("qwen3-like"), "model name from the manifest")
    .opt("verifier", Some("w8a8"), "verifier variant: fp32 | w8a8")
    .opt("drafter", Some("ngram"), "vanilla | ngram | pruned{90,75,50}")
    .opt("gamma", Some("5"), "speculation depth cap")
    .opt("adaptive-gamma", Some("on"),
         "per-class adaptive draft depth: on (default; learned per task class) | off (--gamma is the fixed depth)")
    .opt("batch", Some("4"), "batch bucket (1 or 4)")
    .opt("sched", Some("fifo"), "admission policy: fifo | spf | priority")
    .opt("plan", Some("elastic"), "step planning: elastic | monolithic")
    .flag("governor", "adaptive precision: audit w8a8 verification, demote to fp32 on drift")
    .opt("prefix-cache", Some("on"), "shared-prefix KV reuse at admission: on | off")
    .opt("prefix-budget-mb", Some("256"), "prefix-cache resident-page budget (MiB)")
    .opt("prefix-page-tokens", Some("16"), "prefix-cache pool page size (tokens)")
    .opt("prefix-mid-stream", Some("on"),
         "snapshot generated continuations into the prefix cache: on | off")
    .opt("paged-rows", Some("on"),
         "batch rows as page-tables over the shared pool: on | off (off = copy-based slabs)")
    .opt("chunked-prefill", Some("on"),
         "admission prefill in chunks riding spare decode slots: on | off (off = monolithic)")
    .flag("warmup", "serve: pre-populate the prefix cache from workload templates at boot")
    .flag("trace", "arm the flight recorder: per-request span events, exported by {\"cmd\":\"trace\"}")
    .opt("replicas", Some("1"), "serve: engine replicas behind the dispatcher (1 = single engine)")
    .opt("dispatch", Some("locality"),
         "serve: replica dispatch policy: locality (prefix-hashing + work stealing) | random")
    .opt("steal-threshold", Some("8"),
         "serve: home-replica queue depth at which requests spill to the shallowest replica")
    .opt("port", Some("7878"), "serve: TCP port")
    .opt("prompt", None, "generate: prompt text")
    .opt("max-new", Some("64"), "generate: new-token budget")
    .opt("temp", Some("0"), "sampling temperature (0 = greedy)")
    .parse_env();

    let cmd = parsed
        .positional
        .first()
        .map(String::as_str)
        .unwrap_or("info")
        .to_string();
    let artifacts = PathBuf::from(parsed.str("artifacts"));
    let model = parsed.str("model");
    let sched = parsed.str("sched");
    let cfg = EngineConfig {
        verifier: parsed.str("verifier"),
        drafter: drafter_kind(&parsed.str("drafter"), parsed.usize("gamma"))?,
        batch: parsed.usize("batch"),
        gamma: parsed.usize("gamma"),
        seed: 0,
        policy: SchedPolicy::parse(&sched)
            .ok_or_else(|| anyhow::anyhow!("unknown sched policy '{sched}'"))?,
        elastic: match parsed.str("plan").as_str() {
            "elastic" => true,
            "monolithic" => false,
            other => bail!("unknown plan mode '{other}' (elastic|monolithic)"),
        },
        governor: if parsed.has("governor") {
            quasar::coordinator::GovernorConfig::on()
        } else {
            Default::default()
        },
        prefix: quasar::coordinator::PrefixCacheConfig {
            enabled: match parsed.str("prefix-cache").as_str() {
                "on" => true,
                "off" => false,
                other => bail!("unknown prefix-cache mode '{other}' (on|off)"),
            },
            budget_bytes: parsed.usize("prefix-budget-mb") << 20,
            page_tokens: parsed.usize("prefix-page-tokens").max(1),
            mid_stream: match parsed.str("prefix-mid-stream").as_str() {
                "on" => true,
                "off" => false,
                other => bail!("unknown prefix-mid-stream mode '{other}' (on|off)"),
            },
            ..Default::default()
        },
        paged_rows: match parsed.str("paged-rows").as_str() {
            "on" => true,
            "off" => false,
            other => bail!("unknown paged-rows mode '{other}' (on|off)"),
        },
        chunked_prefill: match parsed.str("chunked-prefill").as_str() {
            "on" => true,
            "off" => false,
            other => bail!("unknown chunked-prefill mode '{other}' (on|off)"),
        },
        adaptive_gamma: match parsed.str("adaptive-gamma").as_str() {
            "on" => true,
            "off" => false,
            other => bail!("unknown adaptive-gamma mode '{other}' (on|off)"),
        },
        // The cluster stamps per-replica identity when it clones this config.
        replica: 0,
        replicas: 1,
        trace: parsed.has("trace"),
    };

    match cmd.as_str() {
        "info" => {
            let manifest = Manifest::load(&artifacts)?;
            println!("device model : {}", manifest.cost_model.device);
            for (name, m) in &manifest.models {
                println!(
                    "model {name}: {} layers, d={}, vocab={}, {} artifacts, ~{:.1}M params",
                    m.cfg.n_layers, m.cfg.d_model, m.cfg.vocab_size,
                    m.artifacts.len(), m.cfg.n_params() as f64 / 1e6
                );
            }
            Ok(())
        }
        "generate" => {
            let manifest = Manifest::load(&artifacts)?;
            let tok = Tokenizer::load(&manifest.tokenizer_path)?;
            let rt = Rc::new(XlaRuntime::cpu()?);
            let mr = Rc::new(ModelRuntime::load(rt, &manifest, &model)?);
            let mut engine = Engine::new(mr, cfg)?;
            let prompt = parsed
                .get("prompt")
                .map(String::from)
                .unwrap_or_else(|| "question : tom has 1 2 apples .".into());
            let params = GenParams {
                temp: parsed.f64("temp"),
                max_new: parsed.usize("max-new"),
                ..GenParams::default()
            };
            engine.submit(tok.encode(&prompt, true), params, "cli");
            let done = engine.run_to_completion()?;
            let c = &done[0];
            println!("{}", tok.decode(&c.tokens));
            eprintln!(
                "[stats] steps={} L={:.2} alpha={:.2} latency={:.2}s method={}",
                c.stats.steps,
                c.stats.mean_acceptance_len(),
                c.stats.acceptance_rate(),
                c.latency_s,
                engine.cfg.method_name(),
            );
            Ok(())
        }
        "serve" => {
            let manifest = Manifest::load(&artifacts)?;
            let tok = Tokenizer::load(&manifest.tokenizer_path)?;
            let port = parsed.usize("port");
            let warmup = parsed.has("warmup") && cfg.prefix.enabled;
            let dispatch = parsed.str("dispatch");
            let ccfg = ClusterConfig {
                replicas: parsed.usize("replicas").max(1),
                dispatch: DispatchPolicy::parse(&dispatch)
                    .ok_or_else(|| anyhow::anyhow!(
                        "unknown dispatch policy '{dispatch}' (locality|random)"))?,
                steal_threshold: parsed.usize("steal-threshold").max(1),
                ..ClusterConfig::default()
            };
            let n = ccfg.replicas;
            let handle = ClusterHandle::spawn(artifacts, model.clone(), cfg, ccfg, 256)?;
            if warmup {
                // Boot warm-up: cache the workload's per-family templates
                // before accepting the first client. The cluster fans each
                // template to its home replica only.
                let ws = quasar::workload::WorkloadSet::load(&manifest.workloads_path)?;
                let plen = manifest.model(&model)?.cfg.prefill_len / 2;
                let templates: Vec<(Vec<i32>, String)> = ws
                    .templates(plen)?
                    .into_iter()
                    .map(|(task, ids)| (ids, task))
                    .collect();
                let cached = handle.warm_prefix(templates)?;
                eprintln!("[quasar] warm-up cached {cached} prefix templates");
            }
            let listener = std::net::TcpListener::bind(("127.0.0.1", port as u16))?;
            eprintln!("[quasar] serving {model} on 127.0.0.1:{port} ({n} replica(s))");
            let served = quasar::server::serve(listener, handle, tok, 8)?;
            eprintln!("[quasar] shut down after {served} requests");
            Ok(())
        }
        other => bail!("unknown command '{other}' (serve|generate|info)"),
    }
}

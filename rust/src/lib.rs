//! # Quasar — Quantized Self-Speculative Acceleration for Rapid Inference
//!
//! Production-style reproduction of *Quasar: Quantized Self-Speculative
//! Acceleration for Rapid Inference via Memory-Efficient Verification*
//! (Huang & Wen, 2026) as a three-layer rust + JAX + Pallas serving stack:
//!
//! * **L3 (this crate)** — request router, admission scheduler, continuous
//!   batcher, prompt-lookup drafter, rejection-sampling verifier logic,
//!   KV-cache manager, metrics and server. Python never runs on the request
//!   path. Admission runs a lookup → splice → suffix-prefill → snapshot
//!   pipeline over a *paged* prefix store (`coordinator::prefixcache`):
//!   each prompt is longest-prefix-matched against a radix trie of
//!   committed token prefixes whose values are **page-runs** — ordered
//!   references into a refcounted pool of fixed-`page_tokens` KV pages —
//!   so a cached prefix pins `ceil(len/page_tokens)` pages instead of a
//!   `max_seq` row, and one physical page backs every run (and every
//!   concurrent admission) sharing its tokens. The matched run is gathered
//!   page-wise into the prefill scratch, only the remaining suffix tokens
//!   are prefilled at the matched write offset — bit-identical to a cold
//!   prefill because attention is causal, but priced (and executed) at
//!   suffix length — and the committed prompt is snapshotted back as a
//!   paged insert that copies only its divergent tail (tail pages are
//!   copy-on-write). Runs stay keyed by the verifier variant that produced
//!   them; the byte-budget LRU frees pages only at refcount zero and never
//!   touches a leased run. Finished requests extend their cached runs with
//!   full pages of the *generated* continuation (mid-stream snapshots), so
//!   multi-turn resubmits hit past the prompt, and the cache can be
//!   pre-populated from workload templates at boot
//!   ([`coordinator::Engine::warm_prefix`]). The batch rows themselves are
//!   **page-tables over the same pool** (`coordinator::PagedGroup`,
//!   default `paged_rows`): an admitted request's row is an ordered list of
//!   leased page ids, so splicing a cached prefix in is O(pages) refcount
//!   bumps plus at most one partial-tail copy — never a row-sized memcpy —
//!   a finish-time snapshot hands the row's full pages back by reference,
//!   and `leave()` is a lease release. Committed positions are append-only,
//!   so full pages stay immutable and shareable while each row writes only
//!   its private (refs == 1) growth-frontier page; the copy-based slab
//!   backend (`paged_rows: false`) is kept as the A/B reference that CI
//!   holds bit-identical. Admission itself is a *resumable state machine*,
//!   not a blocking prefill (`chunked_prefill`, the default): a request is
//!   admitted as soon as a KV row and one prefill-window slot exist — the
//!   row leases its spliced prefix pages immediately and the remaining
//!   suffix is recorded as `Prefilling { hit, consumed }` request state —
//!   and the suffix is then fed one planner-packed chunk per engine step,
//!   *riding the spare rows of the decode/verify sub-batches the step
//!   executes anyway*, so admission prefill never preempts decoding rows.
//!   Partially-prefilled rows accumulate pool pages chunk by chunk through
//!   the same append-only lease API, the first token samples from the
//!   chunk covering the final prompt position, and only when no
//!   same-variant spare slot exists does a chunk fall back to a dedicated
//!   prefill call — the case the `decode_stall_steps` counter tallies,
//!   while ridden chunks book the avoided call price to
//!   `prefill_stall_saved_s`. The monolithic admission loop
//!   (`chunked_prefill: false`) is kept as the bit-identical A/B
//!   reference, exactly like the slab rows. Each engine step then runs a
//!   plan → gather → execute → scatter → commit pipeline
//!   (`coordinator::plan`): active rows are partitioned into sub-batches
//!   by required function (decode-only vs verify) *and* by verifier
//!   precision, each sub-batch executes through the cheapest exported
//!   (batch bucket, weight variant) pair on the cost model, and pending
//!   prefill chunks pack into whatever spare capacity the chosen buckets
//!   leave, so priced memory traffic tracks useful work instead of the
//!   configured shape — low-occupancy groups stop streaming idle KV
//!   rows, decode-only rows stop paying full verify-chunk traffic, and
//!   scatter writes back only each row's freshly executed `[cached,
//!   cached+chunk)` delta (the skipped prefix traffic is booked to the
//!   `kv_copy_saved_s` stat alongside the admission and snapshot savings).
//!
//! Verification precision is a *serving-time policy*, not an offline A/B
//! pin: the fidelity governor (`coordinator::governor`) shadow re-verifies a
//! sampled fraction of quantized (W8A8) verify sub-batches against the fp32
//! reference, tracks per-request-class top-1 agreement (EWMA with
//! hysteresis), demotes a drifting class to full precision and probes it for
//! re-promotion — auditing the paper's §4.5 "quantization does not flip the
//! top-1" assumption online instead of trusting it. Audit rate, agreement,
//! demotions and per-variant call counts surface through `{"cmd":"stats"}`.
//!
//! Draft depth is the same kind of serving-time policy. Gamma — how many
//! tokens the drafter speculates per step — prices the whole speculative
//! bet: too deep on a low-acceptance workload and every step executes (and
//! streams KV for) positions the verifier then rejects; too shallow on a
//! high-acceptance one and steps are wasted on short chunks. The gamma
//! controller (`coordinator::gamma`) makes depth adaptive *per request
//! class* using the governor's class-key plumbing: every commit records
//! (drafted, accepted) into the submitting class's accepted-per-draft EWMA,
//! and at draft time the engine resolves the row's effective gamma as the
//! class EWMA plus a fixed headroom, clamped to the configured cap — so a
//! chat class that keeps accepting 6-token drafts drifts up toward the cap
//! while an adversarial class collapsing to 0-1 acceptances shrinks to
//! depth 1-2 within a few steps, shedding the rejected-position work
//! without touching outputs (committed tokens are the verifier's greedy
//! stream regardless of depth — CI holds `--adaptive-gamma off` and `on`
//! to equal output checksums). Classes learn *across* requests and turns:
//! a new request of a known class seeds its drafter from the class prior
//! instead of cold-starting at the static default. The class map is
//! bounded (overflow folds into one bucket), the static path
//! (`EngineConfig::adaptive_gamma: false`, `--adaptive-gamma off`) is the
//! bit-identical A/B reference, and per-class depth/acceptance stats
//! surface through `{"cmd":"stats"}` and `BENCH_*.json`.
//!
//! Threading model (serving path, two tiers): pool workers in `server`
//! share one `Sync` [`coordinator::ClusterHandle`] with no outer lock. The
//! top tier is a stateless-per-request dispatch plane
//! (`coordinator::cluster`) over N engine replicas: each submit is keyed by
//! its prefix *family* (page-aligned prompt-boundary hashes in a
//! [`coordinator::LocalityIndex`] — a cheap probe, never a pool lock) and
//! consistent-hashed onto the replica whose paged pool already holds its
//! pages, with work-stealing spillover to the shallowest replica when the
//! home queue crosses a threshold (stolen requests admit cold and are
//! priced as cold admissions). The bottom tier is unchanged: each replica
//! is a full single-threaded engine on its own thread — submissions queue
//! in its admission scheduler (`coordinator::scheduler` — FIFO /
//! shortest-prompt / priority policies, deadlines, cancellation, an id
//! index for O(1) cancel probes) and the engine thread drains them into its
//! continuous batcher, routing each completion back to the submitter's
//! private reply channel by request id. Replica r of N mints request ids
//! `r + 1, r + 1 + N, …`, so cancels route by `(id − 1) mod N` with no
//! shared allocator, and replicas share nothing at steady state (engine
//! construction is serialized behind a boot lock for the PJRT runtime).
//! `--replicas 1` collapses the dispatcher to a pass-through that is
//! bit-identical to a bare [`coordinator::EngineHandle`] — the A/B
//! reference CI holds to equal output checksums. Nothing ever blocks on
//! another connection's generation, so concurrent connections genuinely
//! share each batched verification pass — the memory-bandwidth lever the
//! paper's quantized verifier optimizes — while the fleet's `stats`
//! aggregate per-replica occupancy, steal and locality-hit counters.
//! ## Observability
//!
//! Three read-only planes ride on the serving path, all wired through the
//! same JSON-lines protocol (`server`):
//!
//! * **Flight recorder** (`trace`) — a bounded, lock-free, per-thread
//!   ring-buffer of typed span events (enqueue, dispatch/steal, admission
//!   with prefix-hit size, prefill-chunk mode, step plan, per-sub-batch
//!   execution, scatter, commit with acceptance length, audit,
//!   demote/promote, cancel, finish), each stamped with a monotonic
//!   microsecond timestamp and the request's ticket id as the causal key.
//!   Armed by `EngineConfig::trace` (default **off**; the disabled path is
//!   one relaxed atomic load — no clock read, no allocation). Rings
//!   overwrite oldest on wrap and count what they dropped. `{"cmd":"trace"}`
//!   drains the fleet-shared recorder as Chrome trace-event JSON that
//!   Perfetto loads directly: one process track per replica, one async lane
//!   per request.
//! * **Stage attribution** (`coordinator::StageBreakdown`) — every
//!   completion carries a six-way partition of its observed latency
//!   (queue, dispatch, prefix-splice, suffix-prefill, decode, emit);
//!   clients opt in per request with `"stages": true` and
//!   `serve_benchmark` folds the stages into per-stage p50/p99 bench
//!   fields plus a `--slow-log-ms` structured exemplar line.
//! * **Prometheus exposition** (`metrics`) — `{"cmd":"metrics"}` renders
//!   the engine's counters and log-bucket histograms (cumulative
//!   `_bucket`/`le` lines) in the text exposition format, merged across
//!   replicas; `{"cmd":"stats"}` carries provenance alongside (uptime,
//!   crate version, config echo).
//!
//! * **L2** — the target LM as a JAX graph (`python/compile/model.py`),
//!   AOT-lowered to HLO text per (variant, fn, batch-bucket).
//! * **L1** — the fused W8A8 verification GEMM as a Pallas kernel
//!   (`python/compile/kernels/quant_matmul.py`).
//!
//! Entry points: [`runtime::Manifest`] + [`runtime::ModelRuntime`] to load
//! artifacts, [`coordinator::Engine`] to serve, `rust/benches/` to
//! regenerate every table and figure of the paper (DESIGN.md §4).

pub mod bench;
pub mod coordinator;
pub mod evalsuite;
pub mod metrics;
pub mod perfmodel;
pub mod runtime;
pub mod server;
pub mod spec;
pub mod tokenizer;
pub mod trace;
pub mod util;
pub mod workload;

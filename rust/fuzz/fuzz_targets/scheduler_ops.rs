//! Fuzz the admission scheduler with byte-driven op interleavings:
//! push / pop / cancel / take_expired across all three policies, checked
//! against a trivial set model. The scheduler sits between every submitter
//! and the engine's KV rows, so the invariants are accounting exactness:
//! depth mirrors the live set, ids never duplicate or leak, cancel hits
//! exactly the queued ids, expiry drains only deadline-carrying requests,
//! and the peak-depth high-water mark never runs behind the live depth.
#![no_main]

use std::collections::HashSet;
use std::time::{Duration, Instant};

use libfuzzer_sys::fuzz_target;
use quasar::coordinator::{GenParams, Priority, Request, SchedPolicy, Scheduler};

fuzz_target!(|data: &[u8]| {
    let mut bytes = data.iter().copied();
    let policy = match bytes.next().unwrap_or(0) % 3 {
        0 => SchedPolicy::Fifo,
        1 => SchedPolicy::ShortestPromptFirst,
        _ => SchedPolicy::Priority,
    };
    let mut sched = Scheduler::new(policy);
    let t0 = Instant::now();
    let mut live: HashSet<u64> = HashSet::new();
    let mut next_id = 1u64;

    while let Some(op) = bytes.next() {
        match op % 4 {
            0 => {
                let arg = bytes.next().unwrap_or(0);
                let id = next_id;
                next_id += 1;
                let params = GenParams {
                    priority: match arg % 3 {
                        0 => Priority::High,
                        1 => Priority::Normal,
                        _ => Priority::Low,
                    },
                    // Some already-expired, some far-future, some none.
                    deadline: match arg % 5 {
                        0 => Some(Duration::ZERO),
                        1 | 2 => Some(Duration::from_secs(3600)),
                        _ => None,
                    },
                    ..GenParams::default()
                };
                let prompt = vec![1i32; (arg as usize % 7) + 1];
                sched.push(Request::new(id, prompt, params).with_submitted_at(t0));
                live.insert(id);
            }
            1 => {
                let popped = sched.pop();
                match popped {
                    Some(req) => assert!(live.remove(&req.id), "popped unknown id"),
                    None => assert!(live.is_empty(), "pop missed queued work"),
                }
            }
            2 => {
                // Probe a mix of live, already-gone and never-minted ids.
                let arg = bytes.next().unwrap_or(0) as u64;
                let id = arg % (next_id + 2);
                let hit = sched.cancel(id);
                assert_eq!(
                    hit.is_some(),
                    live.contains(&id),
                    "cancel({id}) disagreed with the model"
                );
                if let Some(req) = hit {
                    assert_eq!(req.id, id);
                    live.remove(&id);
                }
            }
            _ => {
                for req in sched.take_expired(Instant::now()) {
                    assert!(live.remove(&req.id), "expired unknown id");
                    assert!(
                        req.params.deadline.is_some(),
                        "expired a deadline-free request"
                    );
                }
            }
        }
        assert_eq!(sched.depth(), live.len(), "depth diverged from live set");
        assert_eq!(sched.is_empty(), live.is_empty());
        assert!(sched.peak_depth() >= sched.depth());
        for &id in &live {
            assert!(sched.contains(id), "live id {id} vanished from the index");
        }
    }
});

//! Fuzz the JSON-lines protocol parser (`quasar::server::parse_request`)
//! with arbitrary bytes. The parser fronts the TCP socket, so its contract
//! is totality: any input — malformed JSON, wrong types, huge / non-finite
//! numbers, unknown commands — returns `Err`, never panics. Accepted
//! requests must additionally satisfy the invariants the engine relies on
//! (already found one real crash: `deadline_ms: 1e999` used to reach
//! `Duration::from_secs_f64(inf)`).
#![no_main]

use libfuzzer_sys::fuzz_target;
use quasar::server::{parse_request, WireRequest};

fuzz_target!(|data: &[u8]| {
    let Ok(line) = std::str::from_utf8(data) else { return };
    match parse_request(line) {
        Err(_) => {} // rejection is always a legal outcome
        Ok(WireRequest::Command(_)) => {}
        Ok(WireRequest::Generate { prompt: _, params, task: _, stages: _ }) => {
            // The wire path always stops at EOS.
            assert!(params.stop_at_eos);
            // The JSON grammar has no NaN literal, so a parsed temperature
            // is never NaN (the sampler divides by max(temp, eps)).
            assert!(!params.temp.is_nan());
            // A parsed deadline is a well-formed Duration by construction
            // (from_secs_f64 would have panicked otherwise); bound it to
            // the parser's documented clamp.
            if let Some(d) = params.deadline {
                assert!(d.as_secs_f64() <= 86_400.0 * 365.0 + 1.0);
            }
        }
    }
});

//! Figure 1 (E1): the verification wall. Per-verify-call latency breakdown
//! (weight stream / KV / activations / compute) on the simulated device for
//! BF16 vs W8A8 verification across speculation depths, plus measured CPU
//! wall per call for the exported artifacts.

use quasar::bench::{BenchCtx, TableWriter};

fn main() {
    quasar::util::bigstack::run(|| run().unwrap())
}

fn run() -> anyhow::Result<()> {
    let ctx = BenchCtx::load()?;
    let mr = ctx.model("qwen3-like")?;
    let perf = ctx.perf(&mr);
    let cfg = mr.cfg().clone();

    let mut table = TableWriter::new(
        "Figure 1 — verify-call latency decomposition (modeled, b=1)",
        &["Variant", "gamma", "weight us", "kv us", "act us", "compute us",
          "total us", "us/token", "bound"],
    );
    for variant in ["fp32", "w8a8"] {
        for gamma in [1usize, 3, 5, 7, 9] {
            let t = perf.price_parts(variant, cfg.n_layers, 1, gamma + 1);
            let mem = t.weight_s + t.kv_s + t.act_s;
            table.row(vec![
                variant.into(),
                gamma.to_string(),
                format!("{:.1}", t.weight_s * 1e6),
                format!("{:.1}", t.kv_s * 1e6),
                format!("{:.1}", t.act_s * 1e6),
                format!("{:.1}", t.compute_s * 1e6),
                format!("{:.1}", t.total() * 1e6),
                format!("{:.2}", t.total() * 1e6 / (gamma + 1) as f64),
                if mem > t.compute_s { "memory".into() } else { "compute".into() },
            ]);
        }
    }
    table.print();

    // Measured CPU wall per exported verify call (fixed padded chunk).
    let mut table = TableWriter::new(
        "Figure 1b — measured CPU wall per verify call (padded chunk, b=1)",
        &["Variant", "ms/call (steady)"],
    );
    for variant in ["fp32", "w8a8"] {
        let toks = vec![5i32; cfg.verify_len()];
        let (k, v) = mr.empty_cache(cfg.n_layers, 1);
        mr.run_chunk(variant, "verify", 1, &toks, &k, &v, &[0])?; // compile
        let t0 = std::time::Instant::now();
        let n = 10;
        for _ in 0..n {
            mr.run_chunk(variant, "verify", 1, &toks, &k, &v, &[0])?;
        }
        table.row(vec![
            variant.into(),
            format!("{:.2}", t0.elapsed().as_secs_f64() * 1e3 / n as f64),
        ]);
    }
    table.print();
    Ok(())
}

//! Table 3 (E4): sensitivity to the prompt-lookup range K = (k_min, k_max)
//! and speculation depth gamma, on the HumanEval profile, Ngram vs Quasar.
//! Adaptive depth is disabled (fixed-gamma sweep, as in the paper).

use quasar::bench::{prompts_for, run_method, speed, BenchCtx, TableWriter};
use quasar::coordinator::{DrafterKind, EngineConfig};
use quasar::spec::NgramConfig;

fn main() {
    quasar::util::bigstack::run(|| run().unwrap())
}

fn cfg_for(verifier: &str, k: (usize, usize), gamma: usize) -> EngineConfig {
    EngineConfig {
        verifier: verifier.into(),
        drafter: DrafterKind::Ngram(NgramConfig {
            k_min: k.0,
            k_max: k.1,
            gamma,
            adaptive: false,
        }),
        batch: 1,
        gamma,
        seed: 0,
        policy: Default::default(),
        elastic: true,
        governor: Default::default(),
        prefix: Default::default(),
        paged_rows: true,
        chunked_prefill: true,
        replica: 0,
        replicas: 1,
        trace: false,
    }
}

fn run() -> anyhow::Result<()> {
    let ctx = BenchCtx::load()?;
    let n = ctx.n_prompts(4);
    let max_new = ctx.max_new(48);
    let mr = ctx.model("qwen3-like")?;
    let perf = ctx.perf(&mr);
    let items = prompts_for(&ctx, "humaneval", n, 33)?;
    let base = run_method(&mr, &perf, EngineConfig::vanilla(1), &items, 0.0, max_new)?;

    let gammas = [3usize, 5, 7, 9];
    let mut table = TableWriter::new(
        &format!("Table 3 — K x gamma sensitivity, HumanEval, qwen3-like (n={n})"),
        &["K", "Method", "Metric", "g=3", "g=5", "g=7", "g=9"],
    );
    for k in [(1, 3), (2, 4), (3, 5)] {
        for verifier in ["fp32", "w8a8"] {
            let method = if verifier == "w8a8" { "Quasar" } else { "Ngram" };
            let mut speeds = Vec::new();
            let mut ls = Vec::new();
            for &g in &gammas {
                let res = run_method(&mr, &perf, cfg_for(verifier, k, g), &items, 0.0, max_new)?;
                speeds.push(speed(res.speedup_vs(&base)));
                ls.push(format!("{:.2}", res.mean_l()));
                eprintln!("[tab3] K={k:?} {method} g={g}: L={}", ls.last().unwrap());
            }
            let kname = format!("({}, {})", k.0, k.1);
            let mut c = vec![kname.clone(), method.into(), "Speed".into()];
            c.extend(speeds);
            table.row(c);
            let mut c = vec![kname, method.into(), "L".into()];
            c.extend(ls);
            table.row(c);
        }
    }
    table.print();
    Ok(())
}

//! Table 4 (E5): downstream accuracy of the W8A8 verifier vs the BF16
//! stand-in — teacher-forced top-1 agreement, perplexity delta and KL on
//! held-out rows per task family (evalsuite; DESIGN.md §1 substitution).

use quasar::bench::{BenchCtx, TableWriter};
use quasar::evalsuite::{compare_task, load_evalset};

fn main() {
    quasar::util::bigstack::run(|| run().unwrap())
}

fn run() -> anyhow::Result<()> {
    let ctx = BenchCtx::load()?;
    let max_rows = ctx.n_prompts(16); // rows per task
    for model in ["qwen3-like", "pangu-like"] {
        let Ok(mr) = ctx.model(model) else { continue };
        let rows = load_evalset(&ctx.manifest.evalset_path)?;
        let mut table = TableWriter::new(
            &format!("Table 4 — accuracy: {model} fp32 vs w8a8 ({max_rows} rows/task)"),
            &["Benchmark", "Top-1 agree", "PPL fp32", "PPL w8a8", "Delta", "mean KL"],
        );
        let mut deltas = Vec::new();
        let mut agrees = Vec::new();
        for (task, rs) in &rows {
            let r = compare_task(&mr, task, rs, max_rows)?;
            deltas.push(r.ppl_delta_pct());
            agrees.push(r.top1_agreement);
            table.row(vec![
                task.clone(),
                format!("{:.1}%", r.top1_agreement * 100.0),
                format!("{:.3}", r.ppl_fp32),
                format!("{:.3}", r.ppl_w8a8),
                format!("{:+.2}%", r.ppl_delta_pct()),
                format!("{:.2e}", r.mean_kl),
            ]);
            eprintln!("[tab4] {model}/{task}: agree={:.3} dPPL={:+.2}%",
                      r.top1_agreement, r.ppl_delta_pct());
        }
        table.row(vec![
            "Average".into(),
            format!("{:.1}%", agrees.iter().sum::<f64>() / agrees.len() as f64 * 100.0),
            "-".into(), "-".into(),
            format!("{:+.2}%", deltas.iter().sum::<f64>() / deltas.len() as f64),
            "-".into(),
        ]);
        table.print();
    }
    Ok(())
}

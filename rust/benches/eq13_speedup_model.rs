//! E7: sanity of the paper's §3.4 analytic model. Compares Eq. 13 closed-form
//! speedups (with measured alpha plugged in) against the engine-measured
//! modeled speedups, across gamma, for both verifier variants.

use quasar::bench::{prompts_for, run_method, speed, BenchCtx, TableWriter};
use quasar::coordinator::{DrafterKind, EngineConfig};
use quasar::spec::NgramConfig;

fn main() {
    quasar::util::bigstack::run(|| run().unwrap())
}

fn run() -> anyhow::Result<()> {
    let ctx = BenchCtx::load()?;
    let n = ctx.n_prompts(4);
    let max_new = ctx.max_new(48);
    let mr = ctx.model("qwen3-like")?;
    let perf = ctx.perf(&mr);
    let items = prompts_for(&ctx, "gsm8k", n, 77)?;
    let base = run_method(&mr, &perf, EngineConfig::vanilla(1), &items, 0.0, max_new)?;

    let mut table = TableWriter::new(
        "Eq. 13 closed form vs engine measurement (GSM8k, qwen3-like)",
        &["Variant", "gamma", "alpha (meas)", "Eq13 speedup", "Engine speedup"],
    );
    for verifier in ["fp32", "w8a8"] {
        for gamma in [3usize, 5, 7] {
            let cfg = EngineConfig {
                verifier: verifier.into(),
                drafter: DrafterKind::Ngram(NgramConfig {
                    gamma, adaptive: false, ..Default::default()
                }),
                batch: 1,
                gamma,
                seed: 0,
                policy: Default::default(),
                elastic: true,
                governor: Default::default(),
                prefix: Default::default(),
                paged_rows: true,
                chunked_prefill: true,
                replica: 0,
                replicas: 1,
                trace: false,
            };
            let res = run_method(&mr, &perf, cfg, &items, 0.0, max_new)?;
            let alpha = res.stats.acceptance_rate();
            // draft cost per step: host-side lookup of ~gamma tokens
            let t_draft = gamma as f64 * ctx.manifest.cost_model.drafter_cost_per_token_s;
            let eq13 = perf.eq13_speedup(verifier, gamma, alpha, t_draft);
            table.row(vec![
                verifier.into(),
                gamma.to_string(),
                format!("{alpha:.2}"),
                speed(eq13),
                speed(res.speedup_vs(&base)),
            ]);
        }
    }
    table.print();
    println!("\nNote: Eq. 13 assumes every step proposes a full gamma-token
draft; the engine only drafts when the n-gram lookup hits, so the closed
form upper-bounds the measured speedup. Shape (ordering, w8a8 > fp32,
diminishing returns in gamma) should agree.");
    Ok(())
}

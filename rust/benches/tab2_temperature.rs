//! Table 2 (E3): robustness across sampling temperatures T in [0, 1].
//! Ngram (fp32 verify) vs Quasar (w8a8 verify), averaged over all tasks,
//! with the Avg-Drop summary row.

use quasar::bench::{run_method, speed, BenchCtx, TableWriter};
use quasar::coordinator::EngineConfig;
use quasar::util::rng::Pcg;

fn main() {
    quasar::util::bigstack::run(|| run().unwrap())
}

fn run() -> anyhow::Result<()> {
    let ctx = BenchCtx::load()?;
    let n = ctx.n_prompts(10); // mixed over the 5 tasks
    let max_new = ctx.max_new(48);
    let mr = ctx.model("qwen3-like")?;
    let perf = ctx.perf(&mr);
    let items = ctx.workloads.mixed(n, &mut Pcg::seeded(0x7AB2))?;

    let temps = [0.0, 0.2, 0.4, 0.6, 0.8, 1.0];
    let mut table = TableWriter::new(
        &format!("Table 2 — temperature sweep, qwen3-like ({n} mixed prompts)"),
        &["Temperature", "Ngram Speed", "Ngram L", "Quasar Speed", "Quasar L"],
    );
    let base = run_method(&mr, &perf, EngineConfig::vanilla(1), &items, 0.0, max_new)?;
    let mut first: Option<(f64, f64, f64, f64)> = None;
    let mut last = (0.0, 0.0, 0.0, 0.0);
    for t in temps {
        let ng = run_method(&mr, &perf, EngineConfig::ngram(1, 5), &items, t, max_new)?;
        let qs = run_method(&mr, &perf, EngineConfig::quasar(1, 5), &items, t, max_new)?;
        let row = (ng.speedup_vs(&base), ng.mean_l(), qs.speedup_vs(&base), qs.mean_l());
        table.row(vec![
            format!("T = {t:.1}"),
            speed(row.0), format!("{:.2}", row.1),
            speed(row.2), format!("{:.2}", row.3),
        ]);
        if first.is_none() { first = Some(row); }
        last = row;
        eprintln!("[tab2] T={t}: ngram L={:.2}, quasar L={:.2}", row.1, row.3);
    }
    let f = first.unwrap();
    table.row(vec![
        "Avg. Drop".into(),
        format!("{:+.1}%", (last.0 / f.0 - 1.0) * 100.0),
        format!("{:+.1}%", (last.1 / f.1 - 1.0) * 100.0),
        format!("{:+.1}%", (last.2 / f.2 - 1.0) * 100.0),
        format!("{:+.1}%", (last.3 / f.3 - 1.0) * 100.0),
    ]);
    table.print();
    Ok(())
}

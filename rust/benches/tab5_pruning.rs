//! Table 5 (E6): structural pruning vs Quasar. Layer-dropped drafters
//! (90/75/50% depth, BF16 verify) against Quasar (full depth, W8A8 verify),
//! with L and end-to-end speedup. The pruned drafters cost *real* forward
//! passes, priced at their depth on the simulated device.

use quasar::bench::{run_method, speed, BenchCtx, TableWriter};
use quasar::coordinator::{DrafterKind, EngineConfig};
use quasar::util::rng::Pcg;

fn main() {
    quasar::util::bigstack::run(|| run().unwrap())
}

fn run() -> anyhow::Result<()> {
    let ctx = BenchCtx::load()?;
    let n = ctx.n_prompts(4);
    let max_new = ctx.max_new(48);
    let mr = ctx.model("qwen3-like")?;
    let perf = ctx.perf(&mr);
    let items = ctx.workloads.mixed(n, &mut Pcg::seeded(0x7AB5))?;
    let full_layers = mr.cfg().n_layers;

    let mut table = TableWriter::new(
        &format!("Table 5 — pruning vs Quasar, qwen3-like ({n} mixed prompts)"),
        &["Method", "Retention / Precision", "L", "Speedup"],
    );
    let base = run_method(&mr, &perf, EngineConfig::vanilla(1), &items, 0.0, max_new)?;
    table.row(vec!["Vanilla (Full Model)".into(),
                   "100% Layers / BF16".into(), "1.00".into(), "1.00x".into()]);

    for variant in ["pruned90", "pruned75", "pruned50"] {
        let nl = mr.entry.artifact(variant, "decode", 1)?.n_layers;
        let cfg = EngineConfig {
            verifier: "fp32".into(),
            drafter: DrafterKind::Pruned(variant.into()),
            batch: 1,
            gamma: 5,
            seed: 0,
            policy: Default::default(),
            elastic: true,
            governor: Default::default(),
            prefix: Default::default(),
            paged_rows: true,
            chunked_prefill: true,
            replica: 0,
            replicas: 1,
            trace: false,
        };
        let res = run_method(&mr, &perf, cfg, &items, 0.0, max_new)?;
        table.row(vec![
            format!("Pruned-{}", variant.trim_start_matches("pruned")),
            format!("{}/{} Layers / BF16", nl, full_layers),
            format!("{:.2}", res.mean_l()),
            speed(res.speedup_vs(&base)),
        ]);
        eprintln!("[tab5] {variant}: L={:.2}", res.mean_l());
    }
    let res = run_method(&mr, &perf, EngineConfig::quasar(1, 5), &items, 0.0, max_new)?;
    table.row(vec![
        "Quasar".into(),
        "100% Layers / W8A8".into(),
        format!("{:.2}", res.mean_l()),
        speed(res.speedup_vs(&base)),
    ]);
    table.print();
    Ok(())
}

//! Table 1 + Figure 2 (E2): end-to-end Speed and mean acceptance length L
//! for {Vanilla, Ngram, Quasar} x 5 tasks x {T=0, T=1}, per model.
//!
//! Speed is modeled decode-phase throughput on the simulated 910B2-class
//! device (perfmodel; DESIGN.md §1) over *measured* engine runs — real
//! drafting, real verification numerics, real acceptance. CPU wall-clock is
//! also printed for transparency.
//!
//! Scale via env: QUASAR_BENCH_N (prompts/task), QUASAR_BENCH_TOKENS,
//! QUASAR_BENCH_MODELS (comma list).

use quasar::bench::{prompts_for, run_method, speed, BenchCtx, TableWriter};
use quasar::coordinator::EngineConfig;
use quasar::workload::TASKS;

fn main() {
    quasar::util::bigstack::run(|| run().unwrap())
}

fn run() -> anyhow::Result<()> {
    let ctx = BenchCtx::load()?;
    let n = ctx.n_prompts(4);
    let max_new = ctx.max_new(48);
    let models = std::env::var("QUASAR_BENCH_MODELS")
        .unwrap_or_else(|_| "qwen3-like,pangu-like".into());

    for model in models.split(',') {
        let mr = ctx.model(model)?;
        let perf = ctx.perf(&mr);
        for temp in [0.0, 1.0] {
            let mut table = TableWriter::new(
                &format!("Table 1 — {model}, T={temp} (n={n}/task, {max_new} new tokens)"),
                &["Method", "Metric", "MT-bench", "HumanEval", "GSM8k", "Alpaca", "CNN/DM", "Overall"],
            );
            let mut rows: Vec<(String, Vec<f64>, Vec<f64>)> = Vec::new(); // (method, speeds, ls)
            let mut base: Vec<f64> = Vec::new(); // vanilla tps per task

            for cfg_fn in [EngineConfig::vanilla as fn(usize) -> EngineConfig] {
                let _ = cfg_fn; // (suppress unused-warning pattern)
            }
            let methods: Vec<EngineConfig> = vec![
                EngineConfig::vanilla(1),
                EngineConfig::ngram(1, 5),
                EngineConfig::quasar(1, 5),
            ];
            for cfg in methods {
                let mut speeds = Vec::new();
                let mut ls = Vec::new();
                let mut tps_overall = Vec::new();
                for (ti, task) in TASKS.iter().enumerate() {
                    let items = prompts_for(&ctx, task, n, 100 + ti as u64)?;
                    let res = run_method(&mr, &perf, cfg.clone(), &items, temp, max_new)?;
                    let tps = res.modeled_tps();
                    if cfg.method_name() == "vanilla" {
                        base.push(tps);
                    }
                    speeds.push(tps / base[ti]);
                    ls.push(res.mean_l());
                    tps_overall.push(tps);
                    eprintln!(
                        "[tab1] {model} T={temp} {} {task}: L={:.2} modeled={:.3}s cpu={:.1}s",
                        cfg.method_name(), res.mean_l(), res.modeled_s, res.wall_s
                    );
                }
                rows.push((cfg.method_name(), speeds, ls));
            }
            for (method, speeds, ls) in &rows {
                let overall_speed =
                    speeds.iter().product::<f64>().powf(1.0 / speeds.len() as f64);
                let overall_l = ls.iter().sum::<f64>() / ls.len() as f64;
                let mut cells = vec![method.clone(), "Speed".into()];
                cells.extend(speeds.iter().map(|s| speed(*s)));
                cells.push(speed(overall_speed));
                table.row(cells);
                let mut cells = vec![method.clone(), "L".into()];
                cells.extend(ls.iter().map(|l| format!("{l:.2}")));
                cells.push(format!("{overall_l:.2}"));
                table.row(cells);
            }
            table.print();
        }
    }
    Ok(())
}

"""Closed-lexicon word tokenizer shared by the trainer, the eval sets and the
rust engine.

The reproduction corpus (see ``corpus.py``) is generated from a fixed lexicon,
so a word-level tokenizer with a greedy longest-match fallback is lossless on
every sequence the system ever sees, keeps the vocabulary small (<= 512), and
round-trips exactly — which the rust tokenizer (rust/src/tokenizer.rs)
re-implements and property-tests against the ``tokenizer.json`` emitted here.

Digits are individual tokens so that arithmetic surface forms ("1 7 2") are
copyable span-by-span by the prompt-lookup drafter, mirroring how real LLM
tokenizers make GSM8K-style generations highly draftable.
"""

from __future__ import annotations

import json
from dataclasses import dataclass, field

PAD, BOS, EOS, UNK = "<pad>", "<bos>", "<eos>", "<unk>"
SPECIALS = [PAD, BOS, EOS, UNK]

# ----------------------------------------------------------------------------
# Lexicon. Order matters: token ids are stable across python and rust.
# ----------------------------------------------------------------------------

DIGITS = [str(d) for d in range(10)]

PUNCT = [".", ",", "?", ":", ";", "(", ")", "=", "+", "-", "*", "/", "<", ">",
         "{", "}", "[", "]", "->", "==", "#", "\"", "'"]

NAMES = ["tom", "anna", "ravi", "mei", "liam", "sara", "omar", "ines", "kofi",
         "yuki", "nora", "eli"]

OBJECTS = ["apples", "books", "coins", "cards", "boxes", "pens", "stones",
           "shells", "tokens", "seeds", "cups", "keys"]

VERBS = ["has", "buys", "sells", "finds", "loses", "gives", "takes", "makes",
         "reads", "counts", "keeps", "shares"]

MATH_WORDS = ["plus", "minus", "times", "total", "each", "more", "fewer",
              "left", "altogether", "twice", "half", "sum", "difference",
              "product", "result", "answer", "question", "so", "then", "now",
              "first", "second", "third", "step", "therefore", "equals"]

CODE_WORDS = ["def", "return", "if", "else", "for", "in", "while", "let",
              "fn", "val", "list", "range", "len", "append", "print", "assert",
              "true", "false", "none", "and", "or", "not", "lambda", "sorted",
              "max", "min", "abs", "input", "output", "index", "value", "item",
              "array", "loop", "function", "test", "case", "expect"]

CHAT_WORDS = ["hello", "thanks", "please", "tell", "me", "about", "explain",
              "what", "why", "how", "is", "are", "the", "a", "an", "of", "to",
              "and", "it", "that", "this", "you", "i", "we", "they", "can",
              "could", "would", "like", "good", "great", "idea", "think",
              "know", "help", "sure", "here", "there", "story", "advice",
              "topic", "point", "view", "both", "sides", "agree", "disagree"]

NEWS_WORDS = ["city", "report", "today", "officials", "said", "announced",
              "new", "plan", "will", "year", "people", "local", "market",
              "prices", "rose", "fell", "percent", "company", "team", "won",
              "game", "season", "summary", "article", "according", "statement",
              "project", "building", "river", "north", "south", "east", "west",
              "monday", "friday", "million", "residents", "mayor", "council"]

INSTR_WORDS = ["write", "describe", "compare", "summarize", "translate",
               "rewrite", "give", "example", "short", "long", "formal",
               "informal", "poem", "letter", "email", "recipe", "steps",
               "ingredients", "mix", "bake", "add", "stir", "heat", "serve",
               "draft", "note", "task", "done", "begin", "end", "with",
               "without", "using", "make", "simple", "clear"]

LEXICON = (DIGITS + PUNCT + NAMES + OBJECTS + VERBS + MATH_WORDS + CODE_WORDS
           + CHAT_WORDS + NEWS_WORDS + INSTR_WORDS)


@dataclass
class Tokenizer:
    """Word-level tokenizer over the closed reproduction lexicon."""

    vocab: list[str] = field(default_factory=list)
    index: dict[str, int] = field(default_factory=dict)

    @classmethod
    def build(cls) -> "Tokenizer":
        vocab: list[str] = []
        for w in SPECIALS + LEXICON:
            if w not in vocab:
                vocab.append(w)
        index = {w: i for i, w in enumerate(vocab)}
        return cls(vocab=vocab, index=index)

    # -- core api -------------------------------------------------------------

    @property
    def vocab_size(self) -> int:
        return len(self.vocab)

    @property
    def pad_id(self) -> int:
        return self.index[PAD]

    @property
    def bos_id(self) -> int:
        return self.index[BOS]

    @property
    def eos_id(self) -> int:
        return self.index[EOS]

    @property
    def unk_id(self) -> int:
        return self.index[UNK]

    def encode(self, text: str, add_bos: bool = False,
               add_eos: bool = False) -> list[int]:
        ids = [self.bos_id] if add_bos else []
        for word in text.split():
            ids.append(self.index.get(word, self.unk_id))
        if add_eos:
            ids.append(self.eos_id)
        return ids

    def decode(self, ids: list[int], skip_special: bool = True) -> str:
        words = []
        for i in ids:
            if i < 0 or i >= len(self.vocab):
                words.append(UNK)
                continue
            w = self.vocab[i]
            if skip_special and w in SPECIALS:
                continue
            words.append(w)
        return " ".join(words)

    # -- serialization --------------------------------------------------------

    def to_json(self) -> str:
        return json.dumps(
            {
                "kind": "closed-lexicon-word",
                "vocab": self.vocab,
                "pad_id": self.pad_id,
                "bos_id": self.bos_id,
                "eos_id": self.eos_id,
                "unk_id": self.unk_id,
            },
            indent=1,
        )

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.to_json())


def padded_vocab_size(n: int, multiple: int = 64) -> int:
    """Round the vocab up so the unembedding GEMM tiles cleanly on the MXU."""
    return ((n + multiple - 1) // multiple) * multiple

"""AOT export pipeline: the L2 -> L3 bridge.

Produces everything the rust engine consumes, under ``artifacts/``:

  manifest.json                   artifact registry + model configs + cost
                                  constants (the single source of truth the
                                  rust runtime loads)
  tokenizer.json                  closed-lexicon vocab for rust/src/tokenizer.rs
  <model>/ckpt.npz                trained f32 parameters (train.py, cached)
  <model>/weights_fp32.npz        flat weight arrays, HLO argument order
  <model>/weights_w8a8.npz        packed INT8+scales, HLO argument order
  <model>/<variant>_<fn>_b<B>.hlo.txt
                                  HLO *text* per (variant, function, batch
                                  bucket) — weights are ARGUMENTS, not
                                  constants, so the text stays small and the
                                  rust side keeps weights device-resident
  <model>/calibration.json        SmoothQuant m2 metadata (calibrate.py)
  <model>/goldens.json            greedy generations for rust integration tests
  workloads.json                  per-task serving prompts (corpus held-out)
  evalset.json                    teacher-forcing rows for Table 4

Interchange is HLO *text*, not serialized protos: jax >= 0.5 emits 64-bit
instruction ids that xla_extension 0.5.1 rejects; the text parser reassigns
ids (see /opt/xla-example/README.md).

Run via ``make artifacts``; idempotent (skips work whose outputs exist unless
--force). ``--quick`` builds a tiny 2-layer model with few train steps so the
python test-suite can exercise the full pipeline in seconds.
"""

from __future__ import annotations

import argparse
import json
import os
import re
import time
from dataclasses import asdict, replace

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import corpus
from .calibrate import calibrate, save_metadata
from .model import (ModelConfig, PRESETS, empty_cache, forward_chunk,
                    prune_params)
from .tokenizer import Tokenizer, padded_vocab_size
from .train import default_config, train

BATCH_BUCKETS = (1, 4)
PRUNE_FRACS = {"pruned90": 0.9, "pruned75": 0.75, "pruned50": 0.5}

# Cost-model constants for the simulated Ascend-910B2-class device
# (DESIGN.md §1). Numbers follow public 910B specs: ~1.6 TB/s HBM bandwidth,
# ~376 TOPS INT8 / ~188 TFLOPS FP16-class dense compute.
COST_MODEL = {
    "device": "ascend-910b2-sim",
    "hbm_bw_bytes_per_s": 1.6e12,
    "int8_ops_per_s": 376e12,
    "bf16_ops_per_s": 188e12,
    "bytes_per_weight": {"fp32": 2, "w8a8": 1,  # "fp32" plays the paper's BF16
                         "pruned90": 2, "pruned75": 2, "pruned50": 2},
    "kernel_launch_s": 2.0e-5,
    "drafter_cost_per_token_s": 1.0e-6,  # n-gram lookup, host-side
}


# ---------------------------------------------------------------------------
# HLO text lowering (see /opt/xla-example/gen_hlo.py)
# ---------------------------------------------------------------------------


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    # CRITICAL: the default printer elides arrays >8 elements as `{...}`,
    # which the rust-side text parser silently reads back as zeros — the
    # RoPE frequency table became all-ones and every position >0 was rotated
    # wrongly. print_large_constants keeps constants exact. (Weights are
    # parameters, not constants, so the text stays small.)
    opts = xc._xla.HloPrintOptions()
    opts.print_large_constants = True
    # New-style metadata attributes (source_end_line, ...) are rejected by
    # XLA 0.5.1's text parser — strip metadata entirely.
    opts.print_metadata = False
    return comp.as_hlo_module().to_string(opts)


def _keystr(path) -> str:
    """Normalize a jax key-path to ``layers.0.wq.ws`` form."""
    out = []
    for k in path:
        if hasattr(k, "key"):
            out.append(str(k.key))
        elif hasattr(k, "idx"):
            out.append(str(k.idx))
        else:
            out.append(re.sub(r"[^A-Za-z0-9_]", "", str(k)))
    return ".".join(out)


def flatten_with_names(params) -> tuple[list[str], list[jax.Array], object]:
    """Flatten the parameter tree in *jax argument order* with stable names.

    The order returned here is exactly the order the lowered HLO expects its
    leading parameters in — the contract rust relies on (manifest
    ``weight_args``).
    """
    leaves_with_path, treedef = jax.tree_util.tree_flatten_with_path(params)
    names = [_keystr(p) for p, _ in leaves_with_path]
    leaves = [l for _, l in leaves_with_path]
    return names, leaves, treedef


def export_chunk_fn(cfg: ModelConfig, params, batch: int, chunk: int,
                    n_layers: int) -> str:
    """Lower ``forward_chunk`` with weights as leading HLO parameters."""
    _, leaves, treedef = flatten_with_names(params)

    def fn(weights, tokens, k_cache, v_cache, pos):
        tree = jax.tree_util.tree_unflatten(treedef, weights)
        return forward_chunk(tree, cfg, tokens, k_cache, v_cache, pos)

    S, H, hd = cfg.max_seq, cfg.n_heads, cfg.head_dim
    specs = (
        tuple(jax.ShapeDtypeStruct(l.shape, l.dtype) for l in leaves),
        jax.ShapeDtypeStruct((batch, chunk), jnp.int32),
        jax.ShapeDtypeStruct((n_layers, batch, H, S, hd), jnp.float32),
        jax.ShapeDtypeStruct((n_layers, batch, H, S, hd), jnp.float32),
        jax.ShapeDtypeStruct((batch,), jnp.int32),
    )
    lowered = jax.jit(fn).lower(*specs)
    return to_hlo_text(lowered)


# ---------------------------------------------------------------------------
# Cost accounting (feeds rust/src/perfmodel via the manifest)
# ---------------------------------------------------------------------------


def artifact_cost(cfg: ModelConfig, variant: str, batch: int, chunk: int,
                  n_layers: int, weight_bytes_dev: int) -> dict:
    """Analytic per-call cost: bytes moved and MACs, for the roofline model."""
    d, f, H, S, hd = (cfg.d_model, cfg.ffn_dim, cfg.n_heads, cfg.max_seq,
                      cfg.head_dim)
    v = cfg.vocab_size
    tok = batch * chunk
    linear_macs = tok * n_layers * (4 * d * d + 3 * d * f)
    attn_macs = batch * n_layers * H * chunk * S * hd * 2
    unembed_macs = tok * d * v
    kv_bytes = 2 * n_layers * batch * H * S * hd * 4     # cache read traffic
    act_bytes = tok * d * 4 * (n_layers * 8 + 2)
    return {
        "weight_bytes_device": weight_bytes_dev,
        "kv_bytes": kv_bytes,
        "act_bytes": act_bytes,
        "macs": linear_macs + attn_macs + unembed_macs,
        "tokens_per_call": tok,
    }


def weight_nbytes(leaves: list[jax.Array], variant: str) -> int:
    """Device bytes the verifier must *load* per forward pass under the
    paper's accounting: BF16 = 2 B/elt for f32 leaves, INT8 = 1 B."""
    total = 0
    for l in leaves:
        if l.dtype == jnp.int8:
            total += l.size
        else:
            total += l.size * COST_MODEL["bytes_per_weight"].get(variant, 2)
    return total


# ---------------------------------------------------------------------------
# Golden generations for rust integration tests
# ---------------------------------------------------------------------------


def greedy_generate(params, cfg: ModelConfig, prompt_ids: list[int],
                    n_new: int) -> list[int]:
    """Reference greedy decoding through the same chunked path rust uses."""
    k, v = empty_cache(cfg, 1, n_layers=len(params["layers"]))
    P = cfg.prefill_len
    ids = list(prompt_ids)[:P]
    toks = np.zeros((1, P), np.int32)
    toks[0, : len(ids)] = ids
    logits, k, v = forward_chunk(params, cfg, jnp.asarray(toks), k, v,
                                 jnp.zeros((1,), jnp.int32))
    pos = len(ids)
    nxt = int(jnp.argmax(logits[0, pos - 1]))
    out = [nxt]
    for _ in range(n_new - 1):
        logits, k, v = forward_chunk(
            params, cfg, jnp.full((1, 1), nxt, jnp.int32), k, v,
            jnp.asarray([pos], jnp.int32))
        pos += 1
        nxt = int(jnp.argmax(logits[0, 0]))
        out.append(nxt)
    return out


# ---------------------------------------------------------------------------
# Data exports: workloads + eval set
# ---------------------------------------------------------------------------


def export_workloads(tok: Tokenizer, path: str, n_per_task: int = 160,
                     seed: int = 7777) -> None:
    """Held-out serving prompts per task family (never seen in training —
    different seed stream than train.py's)."""
    tasks = {}
    for task in corpus.TASKS:
        docs = corpus.make_task_set(task, n_per_task, seed=seed + hash(task) % 1000)
        tasks[task] = [{
            "prompt": d.prompt,
            "prompt_ids": tok.encode(d.prompt, add_bos=True),
            "reference": d.completion,
            "reference_ids": tok.encode(d.completion),
        } for d in docs]
    with open(path, "w") as f:
        json.dump({"tasks": tasks, "seed": seed}, f)


def export_evalset(tok: Tokenizer, path: str, row_len: int,
                   n_per_task: int = 48, seed: int = 9999) -> None:
    """Teacher-forcing rows for Table 4: ``row_len + 1`` token ids per row
    (prefill consumes ``row_len``, targets are shifted by one)."""
    tasks = {}
    for task in corpus.TASKS:
        docs = corpus.make_task_set(task, n_per_task * 2, seed=seed + hash(task) % 1000)
        rows = []
        for d in docs:
            ids = tok.encode(d.text, add_bos=True, add_eos=True)
            if len(ids) < 24:
                continue
            ids = ids[: row_len + 1]
            rows.append({"ids": ids, "len": len(ids)})
            if len(rows) >= n_per_task:
                break
        tasks[task] = rows
    with open(path, "w") as f:
        json.dump({"tasks": tasks, "row_len": row_len}, f)


# ---------------------------------------------------------------------------
# Per-model export
# ---------------------------------------------------------------------------


def export_model(cfg: ModelConfig, out_dir: str, tok: Tokenizer,
                 train_steps: int, force: bool = False,
                 refine_alpha: bool = True) -> dict:
    mdir = os.path.join(out_dir, cfg.name)
    os.makedirs(mdir, exist_ok=True)

    params = train(cfg, out_dir, steps=train_steps)

    # ---- calibration batch: training-mixture docs, fresh seed ------------
    from .train import pack_corpus
    calib_docs = corpus.make_corpus(96, seed=4242)
    calib_rows = pack_corpus(tok, calib_docs)[:16, : cfg.prefill_len]
    qparams, calib_meta = calibrate(params, cfg, jnp.asarray(calib_rows),
                                    refine_alpha=refine_alpha)
    save_metadata(os.path.join(mdir, "calibration.json"), calib_meta)

    variants: dict[str, tuple[dict, int]] = {
        "fp32": (params, cfg.n_layers),
        "w8a8": (qparams, cfg.n_layers),
    }
    for vname, frac in PRUNE_FRACS.items():
        pp = prune_params(params, frac)
        variants[vname] = (pp, len(pp["layers"]))

    # ---- weight npz per variant (pruned share fp32's arrays) -------------
    weights_files = {}
    for vname in ("fp32", "w8a8"):
        vp, _ = variants[vname]
        names, leaves, _ = flatten_with_names(vp)
        wpath = os.path.join(mdir, f"weights_{vname}.npz")
        if force or not os.path.exists(wpath):
            np.savez(wpath, **{n: np.asarray(l) for n, l in zip(names, leaves)})
        weights_files[vname] = f"{cfg.name}/weights_{vname}.npz"

    # ---- HLO artifacts ----------------------------------------------------
    entries = []
    fns = {"prefill": cfg.prefill_len, "decode": 1, "verify": cfg.verify_len}
    for vname, (vp, n_layers) in variants.items():
        names, leaves, _ = flatten_with_names(vp)
        wbytes = weight_nbytes(leaves, vname)
        is_pruned = vname.startswith("pruned")
        buckets = (1,) if is_pruned else BATCH_BUCKETS
        use_fns = ("prefill", "decode") if is_pruned else tuple(fns)
        for fn_name in use_fns:
            chunk = fns[fn_name]
            for b in buckets:
                aname = f"{vname}_{fn_name}_b{b}"
                path = os.path.join(mdir, f"{aname}.hlo.txt")
                if force or not os.path.exists(path):
                    t0 = time.time()
                    text = export_chunk_fn(cfg, vp, b, chunk, n_layers)
                    with open(path, "w") as f:
                        f.write(text)
                    print(f"[aot] {cfg.name}/{aname}: {len(text)/1e6:.2f} MB "
                          f"hlo text ({time.time()-t0:.1f}s)")
                S, H, hd = cfg.max_seq, cfg.n_heads, cfg.head_dim
                entries.append({
                    "name": aname, "variant": vname, "fn": fn_name,
                    "batch": b, "chunk_len": chunk, "n_layers": n_layers,
                    "path": f"{cfg.name}/{aname}.hlo.txt",
                    "weights_file": weights_files["w8a8" if vname == "w8a8"
                                                  else "fp32"],
                    "weight_args": names,
                    "data_args": [
                        {"name": "tokens", "shape": [b, chunk], "dtype": "i32"},
                        {"name": "k_cache",
                         "shape": [n_layers, b, H, S, hd], "dtype": "f32"},
                        {"name": "v_cache",
                         "shape": [n_layers, b, H, S, hd], "dtype": "f32"},
                        {"name": "pos", "shape": [b], "dtype": "i32"},
                    ],
                    "outputs": [
                        {"name": "logits",
                         "shape": [b, chunk, cfg.vocab_size], "dtype": "f32"},
                        {"name": "k_cache",
                         "shape": [n_layers, b, H, S, hd], "dtype": "f32"},
                        {"name": "v_cache",
                         "shape": [n_layers, b, H, S, hd], "dtype": "f32"},
                    ],
                    "cost": artifact_cost(cfg, vname, b, chunk, n_layers,
                                          wbytes),
                })

    # ---- goldens for rust integration tests -------------------------------
    # Tokens are informational; the asserted contract is the *logits* row
    # (rust's XLA 0.5.1 and jax's XLA fuse differently, so argmax can flip on
    # near-ties — logits agree to ~1e-4 relative).
    gpath = os.path.join(mdir, "goldens.json")
    if force or not os.path.exists(gpath):
        goldens = []
        grng = np.random.default_rng(31337)
        for task in ("gsm8k", "mtbench"):
            doc = corpus.make_task_set(task, 1, seed=int(grng.integers(1e6)))[0]
            pid = tok.encode(doc.prompt, add_bos=True)
            entry = {"task": task, "prompt_ids": pid,
                     "greedy_fp32": greedy_generate(params, cfg, pid, 24),
                     "greedy_w8a8": greedy_generate(qparams, cfg, pid, 24)}
            for vname, vp in (("fp32", params), ("w8a8", qparams)):
                k, v = empty_cache(cfg, 1, n_layers=len(vp["layers"]))
                toks = np.zeros((1, cfg.prefill_len), np.int32)
                toks[0, : len(pid)] = pid[: cfg.prefill_len]
                logits, _, _ = forward_chunk(vp, cfg, jnp.asarray(toks), k, v,
                                             jnp.zeros((1,), jnp.int32))
                row = np.asarray(logits[0, len(pid) - 1], np.float32)
                entry[f"prefill_logits_{vname}"] = [round(float(x), 5)
                                                    for x in row]
            goldens.append(entry)
        with open(gpath, "w") as f:
            json.dump(goldens, f)

    return {
        "config": asdict(cfg), "head_dim": cfg.head_dim,
        "weights": weights_files,
        "calibration": f"{cfg.name}/calibration.json",
        "goldens": f"{cfg.name}/goldens.json",
        "artifacts": entries,
    }


# ---------------------------------------------------------------------------
# Entry point
# ---------------------------------------------------------------------------


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--models", default="qwen3-like,pangu-like")
    ap.add_argument("--train-steps", type=int,
                    default=int(os.environ.get("QUASAR_TRAIN_STEPS", "700")))
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--quick", action="store_true",
                    help="tiny model + minimal steps (pipeline test)")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    tok = Tokenizer.build()
    tok.save(os.path.join(args.out, "tokenizer.json"))

    models = {}
    t0 = time.time()
    if args.quick:
        cfg = ModelConfig(name="tiny-test", vocab_size=padded_vocab_size(
            tok.vocab_size), d_model=64, n_layers=2, n_heads=2, ffn_dim=128,
            max_seq=128, prefill_len=64, gamma_max=4)
        models[cfg.name] = export_model(cfg, args.out, tok, train_steps=30,
                                        force=args.force, refine_alpha=False)
        prefill_len = cfg.prefill_len
    else:
        for name in args.models.split(","):
            cfg = default_config(name.strip())
            models[cfg.name] = export_model(cfg, args.out, tok,
                                            train_steps=args.train_steps,
                                            force=args.force)
            prefill_len = cfg.prefill_len

    export_workloads(tok, os.path.join(args.out, "workloads.json"))
    export_evalset(tok, os.path.join(args.out, "evalset.json"),
                   row_len=prefill_len)

    manifest = {
        "version": 1,
        "tokenizer": "tokenizer.json",
        "workloads": "workloads.json",
        "evalset": "evalset.json",
        "cost_model": COST_MODEL,
        "models": models,
    }
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] done in {time.time()-t0:.0f}s -> {args.out}/manifest.json")


if __name__ == "__main__":
    main()

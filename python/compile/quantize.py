"""W8A8 quantization with enhanced SmoothQuant ("m2") calibration.

Implements the paper's §3.2:

  * smoothing:   Y = W X = (W diag(s)^-1)(diag(s) X)          (Eq. 4)
  * calibration: s_j = max|X_j|^alpha / max|W_j|^(1-alpha)    (Eq. 5)
  * weights:     offline per-output-channel symmetric INT8    (Eq. 6)
  * activations: online per-token dynamic symmetric INT8      (Eq. 7, 9)
  * GEMM:        INT8 x INT8 -> INT32, dequant by dw*dx       (Eq. 8, 10)

Conventions: a linear layer stores ``w`` with shape ``[d_in, d_out]`` and is
applied as ``y = x @ w``; smoothing therefore scales the *rows* of ``w`` up by
``s`` and the activation columns down by ``1/s``... note the paper writes the
transposed orientation (W X), so our ``x / s`` corresponds to its
``diag(s) X`` with ``s_ours = 1 / s_paper``; the algebra is identical.

The "enhanced" (m2) part of the paper's calibration is reproduced as a small
grid refinement of ``alpha`` per layer: instead of one global migration
strength, each linear picks the alpha in ``ALPHA_GRID`` minimizing the
quantized-output MSE on the calibration batch. This is the training-free
analogue of the paper's "optimizes this calibration" sentence.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

EPS = 1e-8
ALPHA_GRID = (0.35, 0.5, 0.65, 0.8)


# ---------------------------------------------------------------------------
# Core quantization ops (pure jnp — shared by ref.py, calibrate.py and tests)
# ---------------------------------------------------------------------------

def quantize_weight(w: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Symmetric per-output-channel INT8 quantization of ``w [k, n]``.

    Returns ``(wq int8 [k, n], ws f32 [n])`` with ``w ~= wq * ws``.
    """
    amax = jnp.max(jnp.abs(w), axis=0)
    ws = jnp.maximum(amax, EPS) / 127.0
    wq = jnp.clip(jnp.round(w / ws[None, :]), -127, 127).astype(jnp.int8)
    return wq, ws.astype(jnp.float32)


def quantize_activation(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Dynamic symmetric per-token (per-row) INT8 quantization of ``x [m, k]``.

    Returns ``(xq int8 [m, k], dx f32 [m, 1])`` with ``x ~= xq * dx``.
    """
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    dx = jnp.maximum(amax, EPS) / 127.0
    xq = jnp.clip(jnp.round(x / dx), -127, 127).astype(jnp.int8)
    return xq, dx.astype(jnp.float32)


def smooth_factors(act_amax: jax.Array, w: jax.Array,
                   alpha: float) -> jax.Array:
    """Eq. 5 per-input-channel smoothing factors.

    ``act_amax [k]`` are calibration-time max-abs activation statistics per
    input channel; ``w [k, n]`` the weight. Activations are divided by ``s``
    and weight rows multiplied by ``s``, migrating quantization difficulty
    from activations to weights with strength ``alpha``.
    """
    w_amax = jnp.max(jnp.abs(w), axis=1)
    s = (jnp.maximum(act_amax, EPS) ** alpha
         / jnp.maximum(w_amax, EPS) ** (1.0 - alpha))
    # Guard degenerate channels so neither side collapses to zero.
    return jnp.clip(s, 1e-4, 1e4).astype(jnp.float32)


def pack_linear(w: jax.Array, act_amax: jax.Array,
                alpha: float) -> dict[str, jax.Array]:
    """Offline weight preparation (§3.3): smooth then quantize ``w [k, n]``.

    Returns the artifact dict the quantized model consumes:
      ``wq int8 [k, n]`` smoothed+quantized weight,
      ``ws f32 [n]``     per-output-channel dequant scale,
      ``inv_s f32 [k]``  the *activation-side* multiplier (1/s), applied
                         online as ``x * inv_s`` (paper Eq. 9's ``x ⊙ s``
                         in its orientation).
    """
    s = smooth_factors(act_amax, w, alpha)
    wq, ws = quantize_weight(w * s[:, None])
    return {"wq": wq, "ws": ws, "inv_s": (1.0 / s).astype(jnp.float32)}


def calibrate_linear(w: jax.Array, act_amax: jax.Array,
                     x_sample: jax.Array) -> tuple[dict[str, jax.Array], float]:
    """m2 refinement: pick the alpha in ``ALPHA_GRID`` minimizing quantized
    output MSE on a calibration sample ``x_sample [m, k]``."""
    y_ref = x_sample @ w
    best, best_alpha, best_err = None, ALPHA_GRID[0], np.inf
    for alpha in ALPHA_GRID:
        packed = pack_linear(w, act_amax, alpha)
        y = ref_quant_linear(x_sample, packed)
        err = float(jnp.mean((y - y_ref) ** 2))
        if err < best_err:
            best, best_alpha, best_err = packed, alpha, err
    return best, best_alpha


def ref_quant_linear(x: jax.Array, packed: dict[str, jax.Array]) -> jax.Array:
    """Pure-jnp oracle of the full W8A8 linear (online path, Eq. 9-10)."""
    xs = x * packed["inv_s"][None, :]
    xq, dx = quantize_activation(xs)
    acc = jax.lax.dot_general(
        xq, packed["wq"], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)
    return acc.astype(jnp.float32) * dx * packed["ws"][None, :]


# ---------------------------------------------------------------------------
# Error metrics used by calibrate.py and the python test-suite
# ---------------------------------------------------------------------------

def relative_error(y: jax.Array, y_ref: jax.Array) -> float:
    num = jnp.linalg.norm((y - y_ref).ravel())
    den = jnp.linalg.norm(y_ref.ravel()) + EPS
    return float(num / den)


def kl_divergence(logits_p: jax.Array, logits_q: jax.Array) -> jax.Array:
    """KL(p || q) per row from two logit tensors ``[..., vocab]``."""
    lp = jax.nn.log_softmax(logits_p, axis=-1)
    lq = jax.nn.log_softmax(logits_q, axis=-1)
    return jnp.sum(jnp.exp(lp) * (lp - lq), axis=-1)

"""Layer-2: the target LM as a JAX compute graph.

A GPT-style decoder (RMSNorm, RoPE, SwiGLU, tied unembedding) exposing a
single entry point — ``forward_chunk`` — that subsumes the three serving
functions the rust coordinator needs, distinguished only by the static chunk
length ``T`` it is exported with (python/compile/aot.py):

  * prefill : T = cfg.prefill_len   (prompt ingestion)
  * decode  : T = 1                 (fallback autoregressive step,
                                     and pruned-drafter steps for Table 5)
  * verify  : T = gamma_max + 1     (the paper's parallel verification pass)

The same graph runs in two weight *variants*:

  * ``fp32``  — full-precision linears (the paper's "BF16" verifier;
                DESIGN.md §1 documents the f32 stand-in), and
  * ``w8a8``  — every transformer linear routed through the fused Pallas
                W8A8 kernel (kernels/quant_matmul.py) with offline-smoothed
                INT8 weights — the Quasar verifier.

Structural-pruning baselines (Table 5) are the same graph over a parameter
tree whose trailing layers were dropped (``prune_params``).

KV cache contract (shared with rust/src/runtime):
  ``k_cache, v_cache : f32 [L, B, H, S, hd]``, advanced functionally; the
  chunk writes positions ``pos_b .. pos_b + T - 1`` per batch row and the
  causal mask guarantees slots ``>= pos_b + T`` are never read, so stale
  bytes beyond the write frontier are harmless (they are overwritten before
  ever becoming attendable).
"""

from __future__ import annotations

from dataclasses import dataclass, replace
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from .kernels.quant_matmul import quant_matmul

# ---------------------------------------------------------------------------
# Configuration
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ModelConfig:
    """Static architecture description, embedded into artifacts/manifest.json."""

    name: str
    vocab_size: int          # padded to a multiple of 64 (MXU tiling)
    d_model: int
    n_layers: int
    n_heads: int
    ffn_dim: int
    max_seq: int = 256
    prefill_len: int = 128
    gamma_max: int = 10
    rope_theta: float = 10000.0

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads

    @property
    def verify_len(self) -> int:
        return self.gamma_max + 1

    def n_params(self) -> int:
        d, f = self.d_model, self.ffn_dim
        per_layer = 4 * d * d + 3 * d * f + 2 * d
        return self.vocab_size * d + self.n_layers * per_layer + d

    def pruned(self, keep_frac: float) -> "ModelConfig":
        """Config of a depth-pruned variant keeping the first layers."""
        keep = max(1, int(round(self.n_layers * keep_frac)))
        return replace(self, name=f"{self.name}-pruned{int(keep_frac * 100)}",
                       n_layers=keep)


def qwen3_like(vocab_size: int) -> ModelConfig:
    """Scaled-down stand-in for Qwen3-8B (DESIGN.md §1 substitution table)."""
    return ModelConfig(name="qwen3-like", vocab_size=vocab_size,
                       d_model=256, n_layers=6, n_heads=8, ffn_dim=768)


def pangu_like(vocab_size: int) -> ModelConfig:
    """Scaled-down stand-in for OpenPangu-7B."""
    return ModelConfig(name="pangu-like", vocab_size=vocab_size,
                       d_model=192, n_layers=5, n_heads=6, ffn_dim=576)


PRESETS = {"qwen3-like": qwen3_like, "pangu-like": pangu_like}

# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------

LINEAR_NAMES = ("wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down")


def init_params(rng: jax.Array, cfg: ModelConfig) -> dict:
    """Scaled-normal init; unembedding is tied to ``embed``."""
    d, f, v = cfg.d_model, cfg.ffn_dim, cfg.vocab_size
    keys = jax.random.split(rng, cfg.n_layers + 1)

    def dense(key, shape, scale):
        return (jax.random.normal(key, shape, jnp.float32)
                * scale / np.sqrt(shape[0]))

    layers = []
    for li in range(cfg.n_layers):
        ks = jax.random.split(keys[li], 7)
        layers.append({
            "ln1": jnp.ones((d,), jnp.float32),
            "wq": dense(ks[0], (d, d), 1.0),
            "wk": dense(ks[1], (d, d), 1.0),
            "wv": dense(ks[2], (d, d), 1.0),
            "wo": dense(ks[3], (d, d), 1.0 / np.sqrt(2 * cfg.n_layers)),
            "ln2": jnp.ones((d,), jnp.float32),
            "w_gate": dense(ks[4], (d, f), 1.0),
            "w_up": dense(ks[5], (d, f), 1.0),
            "w_down": dense(ks[6], (f, d), 1.0 / np.sqrt(2 * cfg.n_layers)),
        })
    return {
        "embed": dense(keys[-1], (v, d), d ** 0.25),
        "layers": layers,
        "ln_f": jnp.ones((d,), jnp.float32),
    }


def prune_params(params: dict, keep_frac: float) -> dict:
    """Table-5 structural pruning: keep the *first* ``keep_frac`` of layers
    (the paper: "retaining the first 75% of layers"), final norm intact."""
    keep = max(1, int(round(len(params["layers"]) * keep_frac)))
    return {"embed": params["embed"], "layers": params["layers"][:keep],
            "ln_f": params["ln_f"]}


# ---------------------------------------------------------------------------
# Building blocks
# ---------------------------------------------------------------------------


def rmsnorm(x: jax.Array, g: jax.Array, eps: float = 1e-5) -> jax.Array:
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * g


def linear(x: jax.Array, w, *, use_kernel: bool = True) -> jax.Array:
    """Variant dispatch: plain f32 GEMM, or the fused Pallas W8A8 kernel when
    ``w`` is a packed-quantized dict (quantize.pack_linear)."""
    if isinstance(w, dict):
        b, t, d = x.shape
        x2 = x.reshape(b * t, d)
        if use_kernel:
            y = quant_matmul(x2, w["wq"], w["ws"], w["inv_s"])
        else:  # pure-jnp fallback used by tests to isolate kernel effects
            from .quantize import ref_quant_linear
            y = ref_quant_linear(x2, w)
        return y.reshape(b, t, -1)
    return x @ w


def rope_tables(cfg: ModelConfig) -> tuple[jax.Array, jax.Array]:
    hd = cfg.head_dim
    inv = 1.0 / (cfg.rope_theta ** (jnp.arange(0, hd, 2) / hd))
    t = jnp.arange(cfg.max_seq)[:, None] * inv[None, :]      # [S, hd/2]
    return jnp.cos(t), jnp.sin(t)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """``x [B, H, T, hd]`` rotated by per-position tables ``[B, T, hd/2]``."""
    x1, x2 = x[..., 0::2], x[..., 1::2]
    c = cos[:, None, :, :]
    s = sin[:, None, :, :]
    out = jnp.stack([x1 * c - x2 * s, x1 * s + x2 * c], axis=-1)
    return out.reshape(x.shape)


def _update_cache(cache_l: jax.Array, new: jax.Array,
                  pos: jax.Array) -> jax.Array:
    """Write ``new [B, H, T, hd]`` into ``cache_l [B, H, S, hd]`` at per-row
    offsets ``pos [B]`` (ragged continuous batching)."""

    def upd(c, n, p):
        return jax.lax.dynamic_update_slice(c, n, (0, p, 0))

    return jax.vmap(upd)(cache_l, new, pos)


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------


def forward_chunk(params: dict, cfg: ModelConfig, tokens: jax.Array,
                  k_cache: jax.Array, v_cache: jax.Array, pos: jax.Array,
                  *, use_kernel: bool = True
                  ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Run ``T`` new tokens through the model against the KV cache.

    Args:
      tokens:  i32 ``[B, T]`` new token ids (positions ``pos_b .. pos_b+T-1``).
      k_cache, v_cache: f32 ``[L, B, H, S, hd]``.
      pos:     i32 ``[B]`` per-row write offsets.
    Returns:
      ``logits f32 [B, T, V]`` (position ``i`` conditions on everything up to
      and including ``tokens[:, i]``), plus the advanced caches.
    """
    n_layers = len(params["layers"])
    B, T = tokens.shape
    H, S, hd = cfg.n_heads, cfg.max_seq, cfg.head_dim

    x = params["embed"][tokens]                              # [B, T, d]

    cos_tab, sin_tab = rope_tables(cfg)
    pos_idx = pos[:, None] + jnp.arange(T)[None, :]          # [B, T]
    cos = cos_tab[pos_idx]                                   # [B, T, hd/2]
    sin = sin_tab[pos_idx]

    # Causal visibility: chunk row i may read cache slot j iff j <= pos + i.
    slot = jnp.arange(S)[None, None, :]                      # [1, 1, S]
    visible = slot <= pos_idx[:, :, None]                    # [B, T, S]
    bias = jnp.where(visible, 0.0, -1e30)[:, None, :, :]     # [B, 1, T, S]

    new_k = []
    new_v = []
    scale = 1.0 / np.sqrt(hd)
    for li in range(n_layers):
        lp = params["layers"][li]
        h = rmsnorm(x, lp["ln1"])
        q = linear(h, lp["wq"], use_kernel=use_kernel)
        k = linear(h, lp["wk"], use_kernel=use_kernel)
        v = linear(h, lp["wv"], use_kernel=use_kernel)
        q = q.reshape(B, T, H, hd).transpose(0, 2, 1, 3)     # [B, H, T, hd]
        k = k.reshape(B, T, H, hd).transpose(0, 2, 1, 3)
        v = v.reshape(B, T, H, hd).transpose(0, 2, 1, 3)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)

        k_full = _update_cache(k_cache[li], k, pos)          # [B, H, S, hd]
        v_full = _update_cache(v_cache[li], v, pos)
        new_k.append(k_full)
        new_v.append(v_full)

        scores = jnp.einsum("bhtd,bhsd->bhts", q, k_full) * scale
        probs = jax.nn.softmax(scores + bias, axis=-1)
        attn = jnp.einsum("bhts,bhsd->bhtd", probs, v_full)
        attn = attn.transpose(0, 2, 1, 3).reshape(B, T, -1)
        x = x + linear(attn, lp["wo"], use_kernel=use_kernel)

        h = rmsnorm(x, lp["ln2"])
        gate = jax.nn.silu(linear(h, lp["w_gate"], use_kernel=use_kernel))
        up = linear(h, lp["w_up"], use_kernel=use_kernel)
        x = x + linear(gate * up, lp["w_down"], use_kernel=use_kernel)

    h = rmsnorm(x, params["ln_f"])
    # Tied unembedding stays f32 in both variants: logit fidelity feeds the
    # rejection sampler directly (paper §3.3 "dequantization restores the
    # logits to high precision").
    logits = h @ params["embed"].T                           # [B, T, V]
    return logits, jnp.stack(new_k), jnp.stack(new_v)


def empty_cache(cfg: ModelConfig, batch: int,
                n_layers: int | None = None) -> tuple[jax.Array, jax.Array]:
    L = cfg.n_layers if n_layers is None else n_layers
    shape = (L, batch, cfg.n_heads, cfg.max_seq, cfg.head_dim)
    return jnp.zeros(shape, jnp.float32), jnp.zeros(shape, jnp.float32)


# ---------------------------------------------------------------------------
# Training-time forward (no cache) and loss
# ---------------------------------------------------------------------------


def forward_train(params: dict, cfg: ModelConfig,
                  tokens: jax.Array) -> jax.Array:
    """Dense causal forward for training: ``tokens [B, S] -> logits [B, S, V]``."""
    B, S = tokens.shape
    H, hd = cfg.n_heads, cfg.head_dim
    x = params["embed"][tokens]
    cos_tab, sin_tab = rope_tables(cfg)
    cos = jnp.broadcast_to(cos_tab[None, :S], (B, S, hd // 2))
    sin = jnp.broadcast_to(sin_tab[None, :S], (B, S, hd // 2))
    bias = jnp.where(
        jnp.tril(jnp.ones((S, S), bool)), 0.0, -1e30)[None, None]
    scale = 1.0 / np.sqrt(hd)
    for lp in params["layers"]:
        h = rmsnorm(x, lp["ln1"])
        q = (h @ lp["wq"]).reshape(B, S, H, hd).transpose(0, 2, 1, 3)
        k = (h @ lp["wk"]).reshape(B, S, H, hd).transpose(0, 2, 1, 3)
        v = (h @ lp["wv"]).reshape(B, S, H, hd).transpose(0, 2, 1, 3)
        q, k = apply_rope(q, cos, sin), apply_rope(k, cos, sin)
        scores = jnp.einsum("bhtd,bhsd->bhts", q, k) * scale
        probs = jax.nn.softmax(scores + bias, axis=-1)
        attn = jnp.einsum("bhts,bhsd->bhtd", probs, v)
        x = x + attn.transpose(0, 2, 1, 3).reshape(B, S, -1) @ lp["wo"]
        h = rmsnorm(x, lp["ln2"])
        x = x + (jax.nn.silu(h @ lp["w_gate"]) * (h @ lp["w_up"])) @ lp["w_down"]
    return rmsnorm(x, params["ln_f"]) @ params["embed"].T


@partial(jax.jit, static_argnames=("cfg",))
def loss_fn(params: dict, cfg: ModelConfig, tokens: jax.Array,
            mask: jax.Array) -> jax.Array:
    """Next-token cross-entropy over positions where ``mask`` is 1."""
    logits = forward_train(params, cfg, tokens[:, :-1])
    targets = tokens[:, 1:]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    m = mask[:, 1:]
    return jnp.sum(nll * m) / jnp.maximum(jnp.sum(m), 1.0)


# ---------------------------------------------------------------------------
# Quantized-variant construction
# ---------------------------------------------------------------------------


def quantize_model(params: dict, act_stats: dict, alphas: dict | None = None
                   ) -> dict:
    """Replace every transformer linear by its packed W8A8 form.

    ``act_stats`` maps ``"{layer}.{linear}" -> per-input-channel amax`` from
    calibrate.py; ``alphas`` the per-linear m2 migration strengths (defaults
    to 0.5 when absent).
    """
    from .quantize import pack_linear
    out_layers = []
    for li, lp in enumerate(params["layers"]):
        q = dict(lp)
        for name in LINEAR_NAMES:
            key = f"{li}.{name}"
            alpha = (alphas or {}).get(key, 0.5)
            q[name] = pack_linear(lp[name], act_stats[key], alpha)
        out_layers.append(q)
    return {"embed": params["embed"], "layers": out_layers,
            "ln_f": params["ln_f"]}

"""Build-time trainer for the reproduction target LM.

Trains the GPT-style model of ``model.py`` on the structured synthetic corpus
(``corpus.py``) with a hand-rolled AdamW (+ cosine schedule, grad clipping —
optax is not available in the offline image). Runs ONCE under
``make artifacts``; checkpoints are cached in ``artifacts/<model>/ckpt.npz``
and training is skipped when the checkpoint already exists.

The point of training (DESIGN.md §1): speculative-decoding dynamics —
prompt-lookup hit rates, acceptance lengths, quantization logit drift — only
exist for a model with a *real* next-token distribution. A few hundred steps
on the templated corpus reaches PPL ~1.5-3 on held-out docs, plenty for the
copy behaviours the paper's benchmarks exercise.

CLI:  python -m compile.train --model qwen3-like --out ../artifacts
"""

from __future__ import annotations

import argparse
import os
import time
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from . import corpus
from .model import ModelConfig, PRESETS, init_params, loss_fn
from .tokenizer import Tokenizer, padded_vocab_size

SEQ_LEN = 128


# ---------------------------------------------------------------------------
# Data pipeline: tokenize docs, pack into fixed-length rows
# ---------------------------------------------------------------------------


def pack_corpus(tok: Tokenizer, docs: list[corpus.Doc],
                seq_len: int = SEQ_LEN) -> np.ndarray:
    """Concatenate ``<bos> doc <eos>`` streams and chunk into ``[N, seq_len+1]``
    rows (the +1 feeds the shifted next-token loss)."""
    stream: list[int] = []
    for d in docs:
        stream.extend(tok.encode(d.text, add_bos=True, add_eos=True))
    n = len(stream) // (seq_len + 1)
    arr = np.asarray(stream[: n * (seq_len + 1)], np.int32)
    return arr.reshape(n, seq_len + 1)


def batches(rows: np.ndarray, batch: int, steps: int,
            seed: int):
    rng = np.random.default_rng(seed)
    for _ in range(steps):
        idx = rng.integers(0, rows.shape[0], size=batch)
        yield rows[idx]


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------


@dataclass
class AdamWConfig:
    lr: float = 3e-3
    betas: tuple[float, float] = (0.9, 0.95)
    eps: float = 1e-8
    weight_decay: float = 0.01
    warmup: int = 50
    steps: int = 700
    clip: float = 1.0


def adamw_init(params):
    zeros = jax.tree.map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree.map(jnp.zeros_like, params),
            "t": jnp.zeros((), jnp.int32)}


def _schedule(t, oc: AdamWConfig):
    warm = jnp.minimum(t / max(oc.warmup, 1), 1.0)
    prog = jnp.clip((t - oc.warmup) / max(oc.steps - oc.warmup, 1), 0.0, 1.0)
    return oc.lr * warm * 0.5 * (1.0 + jnp.cos(jnp.pi * prog))


def adamw_update(params, grads, state, oc: AdamWConfig):
    t = state["t"] + 1
    lr = _schedule(t.astype(jnp.float32), oc)
    gnorm = jnp.sqrt(sum(jnp.sum(g * g)
                         for g in jax.tree.leaves(grads)) + 1e-12)
    scale = jnp.minimum(1.0, oc.clip / gnorm)
    b1, b2 = oc.betas

    def upd(p, g, m, v):
        g = g * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mh = m / (1 - b1 ** t)
        vh = v / (1 - b2 ** t)
        p = p - lr * (mh / (jnp.sqrt(vh) + oc.eps) + oc.weight_decay * p)
        return p, m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "t": t}, gnorm


# ---------------------------------------------------------------------------
# Checkpoint IO (flat npz; mirrored by the rust npy-lite loader for debug)
# ---------------------------------------------------------------------------


def flatten_params(params) -> dict[str, np.ndarray]:
    out = {"embed": np.asarray(params["embed"]),
           "ln_f": np.asarray(params["ln_f"])}
    for li, lp in enumerate(params["layers"]):
        for k, v in lp.items():
            out[f"layers.{li}.{k}"] = np.asarray(v)
    return out


def unflatten_params(flat: dict[str, np.ndarray]) -> dict:
    n_layers = 1 + max(int(k.split(".")[1]) for k in flat
                       if k.startswith("layers."))
    layers = []
    for li in range(n_layers):
        prefix = f"layers.{li}."
        layers.append({k[len(prefix):]: jnp.asarray(v)
                       for k, v in flat.items() if k.startswith(prefix)})
    return {"embed": jnp.asarray(flat["embed"]), "layers": layers,
            "ln_f": jnp.asarray(flat["ln_f"])}


def save_checkpoint(path: str, params) -> None:
    os.makedirs(os.path.dirname(path), exist_ok=True)
    np.savez(path, **flatten_params(params))


def load_checkpoint(path: str) -> dict:
    with np.load(path) as z:
        return unflatten_params({k: z[k] for k in z.files})


# ---------------------------------------------------------------------------
# Training loop
# ---------------------------------------------------------------------------


def train(cfg: ModelConfig, out_dir: str, steps: int, batch: int = 32,
          seed: int = 0, n_docs: int = 8000, log_every: int = 50) -> dict:
    ckpt = os.path.join(out_dir, cfg.name, "ckpt.npz")
    if os.path.exists(ckpt):
        print(f"[train] {cfg.name}: cached checkpoint {ckpt}")
        return load_checkpoint(ckpt)

    tok = Tokenizer.build()
    docs = corpus.make_corpus(n_docs, seed=seed)
    rows = pack_corpus(tok, docs)
    held = rows[: max(8, rows.shape[0] // 50)]
    rows = rows[held.shape[0]:]
    print(f"[train] {cfg.name}: {cfg.n_params()/1e6:.1f}M params, "
          f"{rows.shape[0]} rows, {steps} steps")

    params = init_params(jax.random.PRNGKey(seed), cfg)
    oc = AdamWConfig(steps=steps)
    state = adamw_init(params)

    grad_fn = jax.jit(jax.value_and_grad(
        lambda p, toks: loss_fn(p, cfg, toks, jnp.ones_like(toks))))
    update = jax.jit(lambda p, g, s: adamw_update(p, g, s, oc))

    t0 = time.time()
    for step, toks in enumerate(batches(rows, batch, steps, seed + 1)):
        loss, grads = grad_fn(params, jnp.asarray(toks))
        params, state, gnorm = update(params, grads, state)
        if step % log_every == 0 or step == steps - 1:
            hl = float(loss_fn(params, cfg, jnp.asarray(held),
                               jnp.ones_like(jnp.asarray(held))))
            print(f"[train] {cfg.name} step {step:4d} loss {float(loss):.3f} "
                  f"held {hl:.3f} ppl {np.exp(hl):.2f} "
                  f"gnorm {float(gnorm):.2f} {time.time()-t0:.0f}s")
    save_checkpoint(ckpt, params)
    print(f"[train] {cfg.name}: saved {ckpt} ({time.time()-t0:.0f}s)")
    return params


def default_config(name: str) -> ModelConfig:
    tok = Tokenizer.build()
    return PRESETS[name](padded_vocab_size(tok.vocab_size))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default="qwen3-like", choices=list(PRESETS))
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--steps", type=int,
                    default=int(os.environ.get("QUASAR_TRAIN_STEPS", "700")))
    ap.add_argument("--batch", type=int, default=32)
    args = ap.parse_args()
    cfg = default_config(args.model)
    train(cfg, args.out, steps=args.steps, batch=args.batch)


if __name__ == "__main__":
    main()

"""Offline SmoothQuant ("m2") calibration pass (paper §3.3, "Offline Weight
Preparation").

Runs the full-precision model over a calibration batch drawn from the
training corpus mixture, records per-input-channel activation ``amax`` for
every transformer linear, then grid-refines the per-linear migration
strength ``alpha`` (quantize.calibrate_linear) and emits:

  * the packed W8A8 parameter tree (consumed by aot.py), and
  * ``calibration.json`` metadata: chosen alphas, per-linear relative output
    error on held-out activations, and the activation-outlier statistics
    that motivate smoothing (max / p99.9 channel ratio).
"""

from __future__ import annotations

import json

import jax
import jax.numpy as jnp
import numpy as np

from .model import (LINEAR_NAMES, ModelConfig, apply_rope, rmsnorm,
                    rope_tables)
from .quantize import calibrate_linear, pack_linear, ref_quant_linear, relative_error


def collect_linear_inputs(params: dict, cfg: ModelConfig,
                          tokens: jax.Array) -> dict[str, jax.Array]:
    """Dense forward that records the input activation of every linear.

    Returns ``"{layer}.{name}" -> x [B*S, d_in]`` (f32). Mirrors
    ``model.forward_train`` exactly — drift between the two is caught by
    ``python/tests/test_calibrate.py::test_stats_forward_matches_train``.
    """
    B, S = tokens.shape
    H, hd = cfg.n_heads, cfg.head_dim
    rec: dict[str, jax.Array] = {}
    x = params["embed"][tokens]
    cos_tab, sin_tab = rope_tables(cfg)
    cos = jnp.broadcast_to(cos_tab[None, :S], (B, S, hd // 2))
    sin = jnp.broadcast_to(sin_tab[None, :S], (B, S, hd // 2))
    bias = jnp.where(jnp.tril(jnp.ones((S, S), bool)), 0.0, -1e30)[None, None]
    scale = 1.0 / np.sqrt(hd)
    for li, lp in enumerate(params["layers"]):
        h = rmsnorm(x, lp["ln1"])
        rec[f"{li}.wq"] = rec[f"{li}.wk"] = rec[f"{li}.wv"] = h.reshape(-1, h.shape[-1])
        q = (h @ lp["wq"]).reshape(B, S, H, hd).transpose(0, 2, 1, 3)
        k = (h @ lp["wk"]).reshape(B, S, H, hd).transpose(0, 2, 1, 3)
        v = (h @ lp["wv"]).reshape(B, S, H, hd).transpose(0, 2, 1, 3)
        q, k = apply_rope(q, cos, sin), apply_rope(k, cos, sin)
        scores = jnp.einsum("bhtd,bhsd->bhts", q, k) * scale
        probs = jax.nn.softmax(scores + bias, axis=-1)
        attn = jnp.einsum("bhts,bhsd->bhtd", probs, v)
        attn = attn.transpose(0, 2, 1, 3).reshape(B, S, -1)
        rec[f"{li}.wo"] = attn.reshape(-1, attn.shape[-1])
        x = x + attn @ lp["wo"]
        h = rmsnorm(x, lp["ln2"])
        rec[f"{li}.w_gate"] = rec[f"{li}.w_up"] = h.reshape(-1, h.shape[-1])
        inter = jax.nn.silu(h @ lp["w_gate"]) * (h @ lp["w_up"])
        rec[f"{li}.w_down"] = inter.reshape(-1, inter.shape[-1])
        x = x + inter @ lp["w_down"]
    return rec


def activation_amax(inputs: dict[str, jax.Array]) -> dict[str, jax.Array]:
    return {k: jnp.max(jnp.abs(v), axis=0) for k, v in inputs.items()}


def outlier_ratio(x_amax: jax.Array) -> float:
    """How outlier-dominated a linear's input channels are: max / median of
    per-channel amax. Large values are exactly what Eq. 5 smoothing fixes."""
    med = float(jnp.median(x_amax))
    return float(jnp.max(x_amax)) / max(med, 1e-8)


def calibrate(params: dict, cfg: ModelConfig, tokens: jax.Array,
              sample_rows: int = 256, refine_alpha: bool = True
              ) -> tuple[dict, dict]:
    """Full calibration: returns ``(quantized_params, metadata)``."""
    inputs = collect_linear_inputs(params, cfg, tokens)
    amax = activation_amax(inputs)

    alphas: dict[str, float] = {}
    report: dict[str, dict] = {}
    q_layers = []
    for li, lp in enumerate(params["layers"]):
        q = dict(lp)
        for name in LINEAR_NAMES:
            key = f"{li}.{name}"
            w = lp[name]
            x_s = inputs[key][:sample_rows]
            if refine_alpha:
                packed, alpha = calibrate_linear(w, amax[key], x_s)
            else:
                alpha = 0.5
                packed = pack_linear(w, amax[key], alpha)
            alphas[key] = alpha
            err = relative_error(ref_quant_linear(x_s, packed), x_s @ w)
            report[key] = {"alpha": alpha, "rel_err": float(err),
                           "outlier_ratio": outlier_ratio(amax[key])}
            q[name] = packed
        q_layers.append(q)

    qparams = {"embed": params["embed"], "layers": q_layers,
               "ln_f": params["ln_f"]}
    meta = {
        "alpha_grid_refined": refine_alpha,
        "n_calibration_tokens": int(np.prod(tokens.shape)),
        "linears": report,
        "mean_rel_err": float(np.mean([r["rel_err"] for r in report.values()])),
        "max_outlier_ratio": float(max(r["outlier_ratio"]
                                       for r in report.values())),
    }
    return qparams, meta


def save_metadata(path: str, meta: dict) -> None:
    with open(path, "w") as f:
        json.dump(meta, f, indent=1)

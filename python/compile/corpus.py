"""Synthetic-but-structured corpus generator for the reproduction model.

The paper evaluates on five Spec-Bench task families (MT-bench, HumanEval,
GSM8K, Alpaca, CNN/DM). What differentiates them for a prompt-lookup drafter
is *how often the generation copies n-grams from the context*: GSM8K-style
reasoning restates question entities and digit chains, code restates
identifiers and test scaffolding, summarization copies some article spans,
chat paraphrases loosely and instruction-following writes mostly fresh text.

Each generator below emits ``(prompt, completion)`` pairs over the closed
lexicon in ``tokenizer.py`` with exactly those echo profiles, so a small LM
trained on this corpus reproduces the paper's per-task draftability ordering
(GSM8K > HumanEval > MT-bench > CNN/DM ~ Alpaca).

Everything is seeded; the same pairs are exported to ``workloads.json`` for
the rust engine (serving prompts) and ``evalset.json`` (Table 4 accuracy).
"""

from __future__ import annotations

import random
from dataclasses import dataclass

from .tokenizer import (CHAT_WORDS, CODE_WORDS, INSTR_WORDS, NAMES,
                        NEWS_WORDS, OBJECTS, VERBS)

TASKS = ["mtbench", "humaneval", "gsm8k", "alpaca", "cnndm"]


@dataclass
class Doc:
    task: str
    prompt: str
    completion: str

    @property
    def text(self) -> str:
        return f"{self.prompt} {self.completion}"


def _num(rng: random.Random, lo: int = 2, hi: int = 99) -> str:
    """Numbers as digit-token sequences, e.g. 47 -> '4 7'."""
    return " ".join(str(rng.randint(lo, hi)))


def _spell(n: int) -> str:
    return " ".join(str(n))


# ---------------------------------------------------------------------------
# GSM8K-like: templated word problems whose solutions restate the question's
# entities and numbers step by step. Highest echo — the drafter's best case.
# ---------------------------------------------------------------------------

def gen_gsm8k(rng: random.Random) -> Doc:
    name = rng.choice(NAMES)
    obj = rng.choice(OBJECTS)
    a = rng.randint(3, 60)
    b = rng.randint(2, 30)
    op = rng.choice(["plus", "minus", "times"])
    if op == "plus":
        res, opw = a + b, "buys"
    elif op == "minus":
        b = min(b, a - 1)
        res, opw = a - b, "loses"
    else:
        a, b = rng.randint(2, 12), rng.randint(2, 9)
        res, opw = a * b, "makes"
    prompt = (f"question : {name} has {_spell(a)} {obj} . {name} {opw} "
              f"{_spell(b)} more {obj} . how many {obj} now ?")
    if op == "times":
        prompt = (f"question : {name} has {_spell(a)} {obj} . {name} makes "
                  f"{_spell(b)} times more . how many {obj} now ?")
    completion = (f"answer : {name} has {_spell(a)} {obj} . step 1 : "
                  f"{_spell(a)} {op} {_spell(b)} equals {_spell(res)} . "
                  f"therefore the answer is {_spell(res)} .")
    return Doc("gsm8k", prompt, completion)


# ---------------------------------------------------------------------------
# HumanEval-like: code with repeated identifiers, a spec echoed in the body
# and an assert scaffold that restates the function name. High echo.
# ---------------------------------------------------------------------------

def gen_humaneval(rng: random.Random) -> Doc:
    fname = rng.choice(CODE_WORDS[23:31])  # sorted/max/min/abs/... as names
    var = rng.choice(["value", "item", "index"])
    k = rng.randint(2, 9)
    prompt = (f"question : def {fname} ( {var} ) : # return {var} plus "
              f"{_spell(k)} for each {var} in list .")
    completion = (f"answer : def {fname} ( {var} ) : return [ {var} + "
                  f"{_spell(k)} for {var} in list ] "
                  f"assert {fname} ( [ {_spell(rng.randint(1, 9))} ] ) "
                  f"== [ {_spell(rng.randint(1, 9) + k)} ] .")
    return Doc("humaneval", prompt, completion)


# ---------------------------------------------------------------------------
# MT-bench-like: two-turn chat; the assistant partially restates the topic
# words but adds fresh framing. Moderate echo.
# ---------------------------------------------------------------------------

def gen_mtbench(rng: random.Random) -> Doc:
    topic = rng.sample(CHAT_WORDS, 3)
    view = rng.choice(["agree", "disagree"])
    prompt = (f"question : tell me about {topic[0]} and {topic[1]} . "
              f"what do you think about {topic[2]} ?")
    completion = (f"answer : sure . about {topic[0]} and {topic[1]} , "
                  f"i think the point is {topic[2]} . both sides can "
                  f"{view} , and that is a good idea .")
    return Doc("mtbench", prompt, completion)


# ---------------------------------------------------------------------------
# CNN/DM-like: a short "article" followed by a summary that copies one span
# verbatim and compresses the rest. Low-moderate echo.
# ---------------------------------------------------------------------------

def gen_cnndm(rng: random.Random) -> Doc:
    who = rng.choice(NEWS_WORDS[3:5] + ["mayor", "council", "company"])
    what = rng.choice(["plan", "project", "statement", "report"])
    day = rng.choice(["monday", "friday"])
    pct = rng.randint(2, 40)
    prompt = (f"question : the city {who} announced a new {what} on {day} . "
              f"local market prices rose {_spell(pct)} percent this year . "
              f"residents said the {what} will help people . summarize .")
    completion = (f"answer : summary : {who} announced a new {what} . "
                  f"prices rose {_spell(pct)} percent .")
    return Doc("cnndm", prompt, completion)


# ---------------------------------------------------------------------------
# Alpaca-like: open instruction, mostly fresh completion. Lowest echo.
# ---------------------------------------------------------------------------

def gen_alpaca(rng: random.Random) -> Doc:
    act = rng.choice(INSTR_WORDS[:7])
    kind = rng.choice(["poem", "letter", "email", "recipe", "note"])
    style = rng.choice(["short", "long", "formal", "informal", "simple"])
    fresh = rng.sample(CHAT_WORDS + NEWS_WORDS + INSTR_WORDS, 8)
    prompt = f"question : {act} a {style} {kind} about {fresh[0]} ."
    completion = ("answer : " + " ".join(fresh[1:7]) + f" . this {kind} is "
                  f"{style} and done .")
    return Doc("alpaca", prompt, completion)


GENERATORS = {
    "gsm8k": gen_gsm8k,
    "humaneval": gen_humaneval,
    "mtbench": gen_mtbench,
    "cnndm": gen_cnndm,
    "alpaca": gen_alpaca,
}

# Training mixture: weight the echo-heavy families a little higher so the
# copy behaviours that speculative decoding exploits are well learnt.
MIX = [("gsm8k", 0.28), ("humaneval", 0.22), ("mtbench", 0.18),
       ("cnndm", 0.17), ("alpaca", 0.15)]


def sample_doc(rng: random.Random) -> Doc:
    r, acc = rng.random(), 0.0
    for task, w in MIX:
        acc += w
        if r <= acc:
            return GENERATORS[task](rng)
    return GENERATORS[MIX[-1][0]](rng)


def make_corpus(n_docs: int, seed: int = 0) -> list[Doc]:
    rng = random.Random(seed)
    return [sample_doc(rng) for _ in range(n_docs)]


def make_task_set(task: str, n: int, seed: int) -> list[Doc]:
    rng = random.Random(seed)
    return [GENERATORS[task](rng) for _ in range(n)]

"""Pure-jnp correctness oracles for the Pallas kernel.

``ref_quant_matmul`` reproduces the kernel's exact arithmetic (same rounding,
same clipping, same accumulation dtype) without any blocking, so the Pallas
implementation must match it bit-for-bit up to f32 reduction order.
``fp_matmul`` is the un-quantized ground truth used for error *bounds*.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

EPS = 1e-8


def fp_matmul(x: jax.Array, w: jax.Array) -> jax.Array:
    """Full-precision ground truth ``x @ w``."""
    return x @ w


def ref_quant_matmul(x: jax.Array, wq: jax.Array, ws: jax.Array,
                     inv_s: jax.Array) -> jax.Array:
    """Unblocked W8A8 linear with the kernel's exact arithmetic."""
    xs = x * inv_s[None, :]
    amax = jnp.max(jnp.abs(xs), axis=1, keepdims=True)
    dx = jnp.maximum(amax, EPS) / 127.0
    xq = jnp.clip(jnp.round(xs / dx), -127, 127).astype(jnp.int8)
    acc = jax.lax.dot_general(
        xq, wq, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)
    return acc.astype(jnp.float32) * dx * ws[None, :]


def quant_error_bound(x: jax.Array, w_amax_rows: jax.Array) -> float:
    """A loose a-priori bound on |quant - fp| per output element.

    Both operands carry at most half-ULP-of-127 relative rounding error;
    with k-term accumulation the worst case grows linearly in k. Used by the
    property tests to assert the kernel's error stays within theory.
    """
    k = x.shape[1]
    x_amax = float(jnp.max(jnp.abs(x)))
    w_amax = float(jnp.max(w_amax_rows))
    step_x = x_amax / 127.0
    step_w = w_amax / 127.0
    return k * (step_x * w_amax + step_w * x_amax + step_x * step_w) * 0.5

"""Layer-1 Pallas kernel: fused W8A8 verification GEMM (paper §3.3).

One kernel fuses the paper's entire online pipeline so activations make a
single HBM->VMEM round-trip:

    smooth (x * inv_s)  ->  dynamic per-row INT8 quant  ->
    INT8 x INT8 -> INT32 GEMM  ->  dequant by (dx * ws)

Hardware adaptation (DESIGN.md §2): the paper targets Ascend INT8 cube units;
here the kernel is tiled for the TPU memory hierarchy instead —

  * grid over (M/bm, N/bn) output tiles; each program holds an
    ``[bm, K]`` f32 activation stripe, a ``[K, bn]`` *int8* weight stripe
    (half the VMEM bytes of bf16 — the paper's bandwidth claim transplanted
    to VMEM residency) and an ``[bm, bn]`` f32 accumulator tile;
  * the inner op is ``dot_general`` with ``preferred_element_type=int32``,
    the MXU-native int8 path (WMMA analogue);
  * the full K dimension stays resident because dynamic per-token
    quantization needs the complete row max before scaling — a two-pass
    K-split variant would double activation traffic for no VMEM relief at
    our sizes (see ``vmem_footprint``).

Must run with ``interpret=True`` on the CPU PJRT backend; real-TPU lowering
emits a Mosaic custom-call the CPU plugin cannot execute. Perf on real
hardware is estimated analytically via ``vmem_footprint``/``mxu_utilization``
(EXPERIMENTS.md §Perf-L1).
"""

from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

EPS = 1e-8
VMEM_BYTES = 16 * 1024 * 1024  # per-core VMEM budget used for the estimates
MXU_DIM = 128                  # systolic array edge


def _kernel(x_ref, wq_ref, ws_ref, inv_s_ref, o_ref):
    """One (bm, bn) output tile of the fused smooth+quant+GEMM+dequant."""
    # Prologue: smoothing (Eq. 9) fused with dynamic per-row quantization.
    xs = x_ref[...] * inv_s_ref[...]                       # [bm, K] f32
    amax = jnp.max(jnp.abs(xs), axis=1, keepdims=True)     # [bm, 1]
    dx = jnp.maximum(amax, EPS) / 127.0
    xq = jnp.clip(jnp.round(xs / dx), -127, 127)
    # INT8 x INT8 -> INT32 GEMM (Eq. 8). On a real TPU this is the MXU int8
    # path (dot_general with preferred_element_type=int32, as in ref.py's
    # oracle). The exported CPU artifact emulates the integer GEMM in f32:
    # XLA 0.5.1's CPU backend runs s8 dots through a scalar loop (~10x
    # slower), while the f32 dot takes the vectorized path AND is exactly
    # integer-accurate here — |products| <= 127^2 and k <= 1024 terms keep
    # every partial sum below 2^24. Bit-equality against the int32 oracle is
    # enforced by python/tests/test_kernel.py.
    acc = jax.lax.dot_general(
        xq, wq_ref[...].astype(jnp.float32), (((1,), (0,)), ((), ())))
    # Epilogue: dequantize for the non-linear layers (Eq. 10).
    o_ref[...] = acc * dx * ws_ref[...]


@functools.partial(jax.jit, static_argnames=("bm", "bn"))
def quant_matmul(x: jax.Array, wq: jax.Array, ws: jax.Array,
                 inv_s: jax.Array, *, bm: int | None = None,
                 bn: int | None = None) -> jax.Array:
    """Fused W8A8 linear ``y ~= (x * inv_s) @ (wq * ws)``.

    Args:
      x:     f32 ``[m, k]`` activations (high precision, un-smoothed).
      wq:    int8 ``[k, n]`` offline-smoothed, per-output-channel quantized
             weight (``quantize.pack_linear``).
      ws:    f32 ``[n]`` weight dequant scales.
      inv_s: f32 ``[k]`` activation-side smoothing multipliers.
      bm/bn: output tile sizes. ``m`` is padded up to a multiple of ``bm``;
             ``n`` and ``k`` must already be multiples of the tile/lane
             sizes (model dims are chosen as multiples of 64).
    Returns:
      f32 ``[m, n]``.
    """
    m, k = x.shape
    k2, n = wq.shape
    assert k == k2, f"contraction mismatch {k} vs {k2}"
    # Block-shape selection. `None` (the default, and what aot.py exports)
    # means a single (m, n) block: under interpret=True the Pallas grid
    # lowers to a sequential XLA while-loop whose per-iteration dynamic
    # slices cost ~10x on CPU while modelling nothing about the TPU -- the
    # straight-line single-block program computes identical numerics. The
    # *tiled* schedule (bm/bn set) is what would ship on real hardware; its
    # VMEM/MXU characteristics are analyzed analytically below
    # (`best_block_shape`, EXPERIMENTS.md §Perf-L1) and its numerics are
    # pinned against the single-block path by the python test-suite.
    if bn is None:
        bn = n
    else:
        for cand in (bn, 256, 128, 64):
            if n % cand == 0:
                bn = cand
                break
        else:
            bn = n
    bm = _ceil_mult(m, 8) if bm is None else min(bm, _ceil_mult(m, 8))
    mp = _ceil_mult(m, bm)
    if mp != m:
        x = jnp.pad(x, ((0, mp - m), (0, 0)))
    grid = (mp // bm, n // bn)
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),      # x stripe
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),      # int8 W stripe
            pl.BlockSpec((1, bn), lambda i, j: (0, j)),      # ws tile
            pl.BlockSpec((1, k), lambda i, j: (0, 0)),       # inv_s (bcast)
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, n), jnp.float32),
        interpret=True,
    )(x, wq, ws[None, :], inv_s[None, :])
    return out[:m]


def _ceil_mult(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


# ---------------------------------------------------------------------------
# Analytic on-TPU estimates (interpret mode gives no hardware signal; these
# numbers feed DESIGN.md §8 / EXPERIMENTS.md §Perf-L1 and the block-shape
# sweep in python/tests/test_kernel.py::test_block_shapes_fit_vmem).
# ---------------------------------------------------------------------------

@dataclass
class TileEstimate:
    bm: int
    bn: int
    k: int
    vmem_bytes: int
    mxu_util: float
    int8_bytes_moved: int
    bf16_bytes_moved: int

    @property
    def traffic_ratio(self) -> float:
        """INT8 weight traffic as a fraction of BF16 — the paper's ~0.5."""
        return self.int8_bytes_moved / max(self.bf16_bytes_moved, 1)


def vmem_footprint(bm: int, bn: int, k: int) -> int:
    """Bytes resident in VMEM for one program instance of ``_kernel``."""
    x_tile = bm * k * 4            # f32 activations
    xs_tile = bm * k * 1           # int8 quantized copy
    w_tile = k * bn * 1            # int8 weights (the 2x saving vs bf16)
    acc = bm * bn * 4              # int32 accumulator
    out = bm * bn * 4              # f32 output tile
    scales = (bn + k + bm) * 4
    return x_tile + xs_tile + w_tile + acc + out + scales


def mxu_utilization(bm: int, bn: int, k: int) -> float:
    """Fraction of MXU lanes busy for the tile GEMM (edge-padding model)."""
    eff_m = bm / _ceil_mult(bm, MXU_DIM)
    eff_n = bn / _ceil_mult(bn, MXU_DIM)
    eff_k = k / _ceil_mult(k, MXU_DIM)
    return eff_m * eff_n * eff_k


def estimate(bm: int, bn: int, m: int, k: int, n: int) -> TileEstimate:
    """Whole-GEMM HBM traffic + per-tile VMEM/MXU estimate for a block shape."""
    grid_m, grid_n = _ceil_mult(m, bm) // bm, _ceil_mult(n, bn) // bn
    # Each grid column re-reads the x stripe; each grid row re-reads W.
    x_traffic = grid_n * m * k * 4
    w_traffic_int8 = grid_m * k * n * 1
    w_traffic_bf16 = grid_m * k * n * 2
    out_traffic = m * n * 4
    return TileEstimate(
        bm=bm, bn=bn, k=k,
        vmem_bytes=vmem_footprint(bm, bn, k),
        mxu_util=mxu_utilization(bm, bn, k),
        int8_bytes_moved=x_traffic + w_traffic_int8 + out_traffic,
        bf16_bytes_moved=2 * (x_traffic // 2) + w_traffic_bf16 + out_traffic,
    )


def best_block_shape(m: int, k: int, n: int) -> tuple[int, int]:
    """Pick (bm, bn) maximizing MXU utilization subject to the VMEM budget,
    breaking ties toward lower HBM traffic."""
    candidates = []
    for bm in (8, 16, 32, 64, 128, 256):
        for bn in (64, 128, 256, 512):
            if n % bn != 0:
                continue
            est = estimate(bm, bn, m, k, n)
            if est.vmem_bytes > VMEM_BYTES:
                continue
            candidates.append((est.mxu_util, -est.int8_bytes_moved, bm, bn))
    if not candidates:
        return 8, 64
    candidates.sort(reverse=True)
    _, _, bm, bn = candidates[0]
    return bm, bn

"""Quantization math: Eq. 4-8 invariants, the m2 alpha refinement and the
error metrics."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.quantize import (ALPHA_GRID, calibrate_linear, kl_divergence,
                              pack_linear, quantize_activation,
                              quantize_weight, ref_quant_linear,
                              relative_error, smooth_factors)


@settings(max_examples=30, deadline=None)
@given(k=st.integers(2, 64), n=st.integers(2, 64), seed=st.integers(0, 2**16))
def test_weight_quant_roundtrip_bound(k, n, seed):
    rng = np.random.default_rng(seed)
    w = rng.standard_normal((k, n)).astype(np.float32) * rng.uniform(0.1, 10)
    wq, ws = quantize_weight(jnp.asarray(w))
    assert wq.dtype == jnp.int8
    assert int(jnp.abs(wq).max()) <= 127
    recon = np.asarray(wq, np.float32) * np.asarray(ws)[None, :]
    # symmetric per-channel quantization: error <= half step per element
    step = np.asarray(ws)[None, :]
    assert (np.abs(recon - w) <= step * (0.5 + 1e-4) + 1e-7).all()


@settings(max_examples=30, deadline=None)
@given(m=st.integers(1, 32), k=st.integers(2, 64), seed=st.integers(0, 2**16))
def test_activation_quant_per_row(m, k, seed):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((m, k)).astype(np.float32)
    xq, dx = quantize_activation(jnp.asarray(x))
    assert xq.shape == x.shape and dx.shape == (m, 1)
    recon = np.asarray(xq, np.float32) * np.asarray(dx)
    assert (np.abs(recon - x) <= np.asarray(dx) * (0.5 + 1e-4) + 1e-7).all()
    # each row uses its own scale: the row max hits (close to) 127
    assert (np.abs(np.asarray(xq)).max(axis=1) >= 126).all()


def test_smoothing_identity_eq4():
    """Eq. 4 is exact in fp64: (W diag(s)^-1)(diag(s) X) == W X."""
    rng = np.random.default_rng(0)
    k, n, m = 32, 16, 8
    w = rng.standard_normal((k, n)).astype(np.float64)
    x = rng.standard_normal((m, k)).astype(np.float64)
    amax = np.abs(x).max(axis=0)
    s = np.asarray(smooth_factors(jnp.asarray(amax), jnp.asarray(w), 0.5),
                   np.float64)
    lhs = (x * (1.0 / s)[None, :]) @ (w * s[:, None])
    np.testing.assert_allclose(lhs, x @ w, rtol=1e-10)


def test_smooth_factors_migrate_difficulty():
    """Channels with larger activation amax get larger s (Eq. 5), shrinking
    the activation range."""
    k, n = 8, 4
    w = np.ones((k, n), np.float32)
    amax = np.linspace(0.1, 100, k).astype(np.float32)
    s = np.asarray(smooth_factors(jnp.asarray(amax), jnp.asarray(w), 0.5))
    assert (np.diff(s) > 0).all()
    flat = np.asarray(smooth_factors(jnp.asarray(amax), jnp.asarray(w), 0.0))
    assert flat.std() < s.std(), "alpha=0 migrates nothing"


def test_calibrate_linear_picks_best_alpha():
    rng = np.random.default_rng(1)
    k, n, m = 64, 32, 128
    x = rng.standard_normal((m, k)).astype(np.float32)
    x[:, ::8] *= 50.0
    w = rng.standard_normal((k, n)).astype(np.float32)
    amax = jnp.asarray(np.abs(x).max(0))
    packed, alpha = calibrate_linear(jnp.asarray(w), amax, jnp.asarray(x))
    assert alpha in ALPHA_GRID
    y_ref = x @ w
    err_best = relative_error(ref_quant_linear(jnp.asarray(x), packed), jnp.asarray(y_ref))
    for a in ALPHA_GRID:
        p = pack_linear(jnp.asarray(w), amax, a)
        err = relative_error(ref_quant_linear(jnp.asarray(x), p), jnp.asarray(y_ref))
        assert err_best <= err + 1e-9, f"alpha {alpha} not optimal vs {a}"


def test_kl_divergence_properties():
    a = jnp.asarray([[1.0, 2.0, 3.0]])
    assert float(kl_divergence(a, a)[0]) == pytest.approx(0.0, abs=1e-6)
    b = jnp.asarray([[3.0, 2.0, 1.0]])
    assert float(kl_divergence(a, b)[0]) > 0.0

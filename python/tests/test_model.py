"""L2 model invariants: chunked == dense forward, incremental cache
consistency, ragged batches, pruning, and the quantized variant's fidelity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.calibrate import calibrate, collect_linear_inputs
from compile.model import (ModelConfig, empty_cache, forward_chunk,
                           forward_train, init_params, loss_fn, prune_params,
                           quantize_model)
from compile.tokenizer import Tokenizer, padded_vocab_size

CFG = ModelConfig(name="t", vocab_size=padded_vocab_size(Tokenizer.build().vocab_size),
                  d_model=64, n_layers=2, n_heads=2, ffn_dim=128,
                  max_seq=64, prefill_len=32, gamma_max=4)


@pytest.fixture(scope="module")
def params():
    return init_params(jax.random.PRNGKey(0), CFG)


@pytest.fixture(scope="module")
def toks():
    return jax.random.randint(jax.random.PRNGKey(1), (2, 20), 4, 250)


def test_chunked_equals_dense(params, toks):
    k, v = empty_cache(CFG, 2)
    chunk, _, _ = forward_chunk(params, CFG, toks, k, v, jnp.zeros(2, jnp.int32))
    dense = forward_train(params, CFG, toks)
    np.testing.assert_allclose(np.asarray(chunk), np.asarray(dense),
                               rtol=2e-4, atol=2e-5)


def test_incremental_cache_exact(params, toks):
    k, v = empty_cache(CFG, 2)
    full, _, _ = forward_chunk(params, CFG, toks, k, v, jnp.zeros(2, jnp.int32))
    k, v = empty_cache(CFG, 2)
    _, k, v = forward_chunk(params, CFG, toks[:, :13], k, v, jnp.zeros(2, jnp.int32))
    part, _, _ = forward_chunk(params, CFG, toks[:, 13:], k, v,
                               jnp.full((2,), 13, jnp.int32))
    np.testing.assert_allclose(np.asarray(part), np.asarray(full[:, 13:]),
                               rtol=1e-5, atol=1e-6)


def test_ragged_positions_per_row(params, toks):
    """Rows at different positions (continuous batching) attend correctly."""
    # row 0 at pos 5, row 1 at pos 11 — both must equal their b=1 runs
    k, v = empty_cache(CFG, 2)
    _, k, v = forward_chunk(params, CFG, toks[:, :12], k, v, jnp.zeros(2, jnp.int32))
    # advance row 0 by feeding 1 token at pos 12 while row 1 feeds pad at 0...
    # simplest exact check: run each row separately and compare to the
    # batched ragged call
    new = jnp.asarray([[7, 8, 9], [100, 101, 102]], jnp.int32)
    pos = jnp.asarray([12, 5], jnp.int32)
    ragged, _, _ = forward_chunk(params, CFG, new, k, v, pos)
    for b in range(2):
        kb = k[:, b:b + 1]
        vb = v[:, b:b + 1]
        single, _, _ = forward_chunk(params, CFG, new[b:b + 1], kb, vb, pos[b:b + 1])
        np.testing.assert_allclose(np.asarray(ragged[b]), np.asarray(single[0]),
                                   rtol=1e-5, atol=1e-6)


def test_stale_slots_beyond_frontier_are_harmless(params, toks):
    """Garbage KV beyond the write frontier must not affect logits (the
    correctness argument for speculative-rejection rollback)."""
    k, v = empty_cache(CFG, 1)
    _, k, v = forward_chunk(params, CFG, toks[:1, :10], k, v, jnp.zeros(1, jnp.int32))
    # poison slots 10.. with garbage
    k_poison = k.at[:, :, :, 10:, :].set(99.0)
    v_poison = v.at[:, :, :, 10:, :].set(-99.0)
    a, _, _ = forward_chunk(params, CFG, toks[:1, 10:12], k, v,
                            jnp.full((1,), 10, jnp.int32))
    b, _, _ = forward_chunk(params, CFG, toks[:1, 10:12], k_poison, v_poison,
                            jnp.full((1,), 10, jnp.int32))
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)


def test_prune_params_keeps_prefix(params):
    p75 = prune_params(params, 0.75)
    assert len(p75["layers"]) == 2  # round(2 * 0.75) = 2
    p50 = prune_params(params, 0.5)
    assert len(p50["layers"]) == 1
    assert p50["layers"][0] is params["layers"][0]
    k, v = empty_cache(CFG, 1, n_layers=1)
    toks = jnp.asarray([[5, 6, 7]], jnp.int32)
    logits, _, _ = forward_chunk(p50, CFG, toks, k, v, jnp.zeros(1, jnp.int32))
    assert logits.shape == (1, 3, CFG.vocab_size)


def test_quantized_model_top1_fidelity(params, toks):
    """After calibration, the w8a8 model's argmax agrees with fp32 on a large
    majority of positions even for a random-init model (trained models agree
    more — checked end-to-end by the rust Table-4 bench)."""
    qp, meta = calibrate(params, CFG, toks, refine_alpha=False)
    k, v = empty_cache(CFG, 2)
    lf, _, _ = forward_chunk(params, CFG, toks, k, v, jnp.zeros(2, jnp.int32))
    kq, vq = empty_cache(CFG, 2)
    lq, _, _ = forward_chunk(qp, CFG, toks, kq, vq, jnp.zeros(2, jnp.int32))
    agree = float((lf.argmax(-1) == lq.argmax(-1)).mean())
    assert agree > 0.8, f"top-1 agreement too low: {agree}"
    assert meta["mean_rel_err"] < 0.05


def test_quantize_model_structure(params, toks):
    stats = {f"{li}.{n}": jnp.ones(CFG.d_model if n != "w_down" else CFG.ffn_dim)
             for li in range(CFG.n_layers)
             for n in ("wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down")}
    qp = quantize_model(params, stats)
    lin = qp["layers"][0]["wq"]
    assert set(lin.keys()) == {"wq", "ws", "inv_s"}
    assert lin["wq"].dtype == jnp.int8


def test_loss_decreases_with_teacher_signal(params):
    """Sanity: loss on a constant sequence is far below random chance after
    even light training dynamics are emulated (here: just check the loss is
    finite and correctly masked)."""
    toks = jnp.full((2, 16), 7, jnp.int32)
    full = float(loss_fn(params, CFG, toks, jnp.ones_like(toks)))
    masked = float(loss_fn(params, CFG, toks, jnp.zeros_like(toks).at[:, :2].set(1)))
    assert np.isfinite(full) and np.isfinite(masked)


def test_collect_linear_inputs_matches_train_forward(params, toks):
    """The calibration forward must stay in lockstep with forward_train."""
    rec = collect_linear_inputs(params, CFG, toks)
    assert set(rec) == {f"{li}.{n}" for li in range(CFG.n_layers)
                        for n in ("wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down")}
    # first-layer qkv input is rmsnorm(embed): verify against direct compute
    from compile.model import rmsnorm
    x = params["embed"][toks]
    h = rmsnorm(x, params["layers"][0]["ln1"]).reshape(-1, CFG.d_model)
    np.testing.assert_allclose(np.asarray(rec["0.wq"]), np.asarray(h), rtol=1e-6)

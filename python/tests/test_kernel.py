"""L1 correctness: the Pallas W8A8 kernel against the pure-jnp oracle.

The CORE correctness signal for the exported artifacts: hypothesis sweeps
shapes/scales/smoothing regimes and asserts the kernel matches ``ref.py``
(same integer accumulation; final dequant multiply may differ by 1 ULP in
f32, hence the tight-but-not-bitwise tolerance) and stays within the a-priori
quantization error bound of the fp32 ground truth.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.quant_matmul import (best_block_shape, estimate,
                                          mxu_utilization, quant_matmul,
                                          vmem_footprint, VMEM_BYTES)
from compile.kernels.ref import fp_matmul, quant_error_bound, ref_quant_matmul
from compile.quantize import quantize_weight, smooth_factors

DIMS = st.sampled_from([64, 128, 192, 256, 320, 768])
SMALL_M = st.integers(min_value=1, max_value=70)


def make_case(seed, m, k, n, x_scale, outlier):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((m, k)).astype(np.float32) * x_scale
    if outlier:
        # systematic per-channel outliers, the regime SmoothQuant targets
        cols = rng.choice(k, size=max(1, k // 32), replace=False)
        x[:, cols] *= 30.0
    w = rng.standard_normal((k, n)).astype(np.float32)
    act_amax = np.abs(x).max(axis=0)
    s = np.asarray(smooth_factors(jnp.asarray(act_amax), jnp.asarray(w), 0.5))
    wq, ws = quantize_weight(jnp.asarray(w * s[:, None]))
    inv_s = (1.0 / s).astype(np.float32)
    return x, w, wq, ws, inv_s


@settings(max_examples=25, deadline=None)
@given(m=SMALL_M, k=DIMS, n=DIMS, x_scale=st.sampled_from([0.1, 1.0, 8.0]),
       outlier=st.booleans(), seed=st.integers(0, 2**16))
def test_kernel_matches_ref_oracle(m, k, n, x_scale, outlier, seed):
    x, _w, wq, ws, inv_s = make_case(seed, m, k, n, x_scale, outlier)
    out = quant_matmul(jnp.asarray(x), wq, ws, jnp.asarray(inv_s))
    ref = ref_quant_matmul(jnp.asarray(x), wq, ws, jnp.asarray(inv_s))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-6, atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(m=SMALL_M, k=DIMS, n=DIMS, seed=st.integers(0, 2**16))
def test_kernel_within_quant_error_bound_of_fp32(m, k, n, seed):
    x, w, wq, ws, inv_s = make_case(seed, m, k, n, 1.0, False)
    out = np.asarray(quant_matmul(jnp.asarray(x), wq, ws, jnp.asarray(inv_s)))
    truth = np.asarray(fp_matmul(jnp.asarray(x), jnp.asarray(w)))
    bound = quant_error_bound(jnp.asarray(x), jnp.abs(jnp.asarray(w)).max(axis=1))
    assert np.abs(out - truth).max() <= bound, (
        f"error {np.abs(out - truth).max()} exceeds bound {bound}")


@settings(max_examples=8, deadline=None)
@given(m=st.sampled_from([8, 33, 64]), seed=st.integers(0, 2**16))
def test_tiled_grid_matches_single_block(m, seed):
    """The exported single-block program and the TPU-notional tiled schedule
    compute identical results (up to f32 dequant rounding)."""
    k, n = 256, 768
    x, _w, wq, ws, inv_s = make_case(seed, m, k, n, 1.0, True)
    single = quant_matmul(jnp.asarray(x), wq, ws, jnp.asarray(inv_s))
    tiled = quant_matmul(jnp.asarray(x), wq, ws, jnp.asarray(inv_s),
                         bm=32, bn=128)
    np.testing.assert_allclose(np.asarray(single), np.asarray(tiled),
                               rtol=1e-6, atol=1e-5)


def test_quantization_actually_compresses():
    """Relative error should be small but non-zero (we are quantizing)."""
    x, w, wq, ws, inv_s = make_case(0, 32, 256, 256, 1.0, False)
    out = np.asarray(quant_matmul(jnp.asarray(x), wq, ws, jnp.asarray(inv_s)))
    truth = np.asarray(fp_matmul(jnp.asarray(x), jnp.asarray(w)))
    rel = np.linalg.norm(out - truth) / np.linalg.norm(truth)
    assert 1e-5 < rel < 0.05, rel
    assert wq.dtype == jnp.int8


def test_smoothing_rescues_outlier_channels():
    """With heavy activation outliers, the smoothed W8A8 path must beat the
    unsmoothed one (inv_s = 1) — the reason SmoothQuant exists."""
    rng = np.random.default_rng(3)
    m, k, n = 64, 256, 256
    x = rng.standard_normal((m, k)).astype(np.float32)
    x[:, ::16] *= 100.0  # brutal outlier channels
    w = rng.standard_normal((k, n)).astype(np.float32)
    truth = x @ w

    # unsmoothed
    wq0, ws0 = quantize_weight(jnp.asarray(w))
    out0 = np.asarray(quant_matmul(jnp.asarray(x), wq0, ws0,
                                   jnp.ones(k, jnp.float32)))
    # smoothed (Eq. 4/5, alpha=0.5)
    s = smooth_factors(jnp.asarray(np.abs(x).max(0)), jnp.asarray(w), 0.5)
    wq1, ws1 = quantize_weight(jnp.asarray(w) * np.asarray(s)[:, None])
    out1 = np.asarray(quant_matmul(jnp.asarray(x), wq1, ws1,
                                   jnp.asarray((1.0 / np.asarray(s)).astype(np.float32))))
    err0 = np.linalg.norm(out0 - truth)
    err1 = np.linalg.norm(out1 - truth)
    assert err1 < err0 * 0.5, f"smoothing should halve error: {err1} vs {err0}"


# ---------------------------------------------------------------------------
# Analytic TPU-schedule checks (EXPERIMENTS.md §Perf-L1)
# ---------------------------------------------------------------------------

def test_block_shapes_fit_vmem():
    """Every model GEMM shape admits a tile that fits VMEM with full MXU
    utilization, and the chosen tile halves weight traffic vs bf16."""
    shapes = [(44, 256, 256), (44, 256, 768), (44, 768, 256),  # qwen3-like
              (4 * 11, 192, 192), (44, 192, 576), (44, 576, 192)]  # pangu-like
    for (m, k, n) in shapes:
        bm, bn = best_block_shape(m, k, n)
        assert vmem_footprint(bm, bn, k) <= VMEM_BYTES
        est = estimate(bm, bn, m, k, n)
        # int8 weights always cut total traffic; activation traffic (equal in
        # both variants) dilutes the 2x weight saving more at small m
        assert est.traffic_ratio < 0.9, (m, k, n, est.traffic_ratio)
    # the weight stream dominates at decode-scale m (the memory-bound regime
    # the paper targets): the full ~2x saving shows through at m=1 and decays
    # monotonically as activation traffic grows
    ratios = [estimate(*best_block_shape(m, 256, 768), m, 256, 768).traffic_ratio
              for m in (1, 11, 44, 512)]
    assert ratios[0] < 0.55, ratios
    assert all(a <= b + 1e-9 for a, b in zip(ratios, ratios[1:])), ratios


def test_mxu_utilization_model():
    assert mxu_utilization(128, 128, 128) == 1.0
    assert mxu_utilization(64, 128, 128) == 0.5
    assert mxu_utilization(128, 128, 64) == pytest.approx(0.5)

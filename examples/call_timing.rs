use std::rc::Rc;
use quasar::runtime::{Manifest, ModelRuntime, XlaRuntime};
fn main() {
    quasar::util::bigstack::run(|| {
        let root = std::path::PathBuf::from("artifacts");
        let rt = Rc::new(XlaRuntime::cpu().unwrap());
        let manifest = Manifest::load(&root).unwrap();
        let mr = Rc::new(ModelRuntime::load(rt, &manifest, "qwen3-like").unwrap());
        let cfg = mr.cfg().clone();
        for variant in ["fp32", "w8a8"] {
            for (f, chunk) in [("verify", cfg.gamma_max + 1), ("decode", 1)] {
                let toks = vec![5i32; chunk];
                let (k, v) = mr.empty_cache(cfg.n_layers, 1);
                // warmup (compile)
                let t0 = std::time::Instant::now();
                mr.run_chunk(variant, f, 1, &toks, &k, &v, &[0]).unwrap();
                let compile_and_first = t0.elapsed().as_secs_f64();
                let t0 = std::time::Instant::now();
                let n = 5;
                for _ in 0..n { mr.run_chunk(variant, f, 1, &toks, &k, &v, &[0]).unwrap(); }
                println!("{variant:>5} {f:>7}: first(incl compile) {:.0}ms, steady {:.1}ms/call",
                    compile_and_first*1e3, t0.elapsed().as_secs_f64()*1e3/n as f64);
            }
        }
    })
}

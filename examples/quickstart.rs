//! Quickstart: load the trained model, generate with Quasar (w8a8 verifier +
//! prompt-lookup drafting), and compare against the Ngram (fp32) baseline.
//!
//! Run: `cargo run --release --example quickstart`

use std::rc::Rc;

use quasar::bench::BenchCtx;
use quasar::coordinator::{Engine, EngineConfig, GenParams};

fn main() {
    quasar::util::bigstack::run(|| {
        if let Err(e) = run() {
            eprintln!("error: {e:#}");
            std::process::exit(1);
        }
    })
}

fn run() -> anyhow::Result<()> {
    let ctx = BenchCtx::load()?;
    let mr = ctx.model("qwen3-like")?;
    let perf = ctx.perf(&mr);
    let prompt = "question : tom has 2 4 apples . tom buys 1 3 more apples . \
                  how many apples now ?";
    let ids = ctx.tok.encode(prompt, true);
    println!("prompt: {prompt}\n");

    for cfg in [EngineConfig::vanilla(1), EngineConfig::ngram(1, 5), EngineConfig::quasar(1, 5)] {
        let name = cfg.method_name();
        let mut engine = Engine::new(Rc::clone(&mr), cfg)?;
        engine.submit(ids.clone(), GenParams::default(), "quickstart");
        let t0 = std::time::Instant::now();
        let done = engine.run_to_completion()?;
        let c = &done[0];
        let modeled = perf.decode_time(&engine.call_log, None);
        println!("[{name:>8}] {}", ctx.tok.decode(&c.tokens));
        println!(
            "           steps={} L={:.2} modeled-decode={:.1}ms cpu-wall={:.0}ms\n",
            c.stats.steps,
            c.stats.mean_acceptance_len(),
            modeled * 1e3,
            t0.elapsed().as_secs_f64() * 1e3
        );
    }
    Ok(())
}

//! End-to-end serving validation (DESIGN.md E8): load the trained model,
//! boot the TCP server, and drive it with N *concurrent closed-loop client
//! connections* through the full stack (TCP -> pool worker -> scheduler ->
//! engine thread -> continuous batcher -> drafter -> PJRT verification).
//! Reports latency / throughput / acceptance plus the scheduler's view:
//! batch occupancy and mean scheduling delay, so the effect of concurrent
//! submission on batched verification is visible directly in the output.
//!
//! `--replicas N` fronts N engine replicas with the locality-hashing
//! dispatcher (work-stealing spillover, `--dispatch random` as the
//! locality-blind control); `--replicas 0` keeps the bare single-engine
//! handle as the dispatcher-free A/B reference, and `--replicas 1` must
//! match it bit for bit (CI's checksum gate).
//!
//! `--scenario` selects a workload shape from the suite in
//! `quasar::workload` — `mixed` (the original round-robin closed loop),
//! `agentic` (multi-turn tool-call loops over family templates), `diurnal`
//! (open-loop bursty trace replay at `--rate` req/s base), `longctx`
//! (long-context summarization) and `thrash` (adversarial cache-thrashing
//! salted prompts). Every run is scored against SLO targets
//! (`--slo-ttft-ms` / `--slo-tpot-ms`): the attainment fractions and
//! per-stage percentiles land on stdout and in the `BENCH_*.json`
//! artifact. `--adaptive-gamma off` pins speculation depth to `--gamma`'s
//! static cap (the per-class controller A/B reference; outputs are
//! bit-identical either way — only drafted-but-rejected work moves).
//!
//! Run: `cargo run --release --example serve_benchmark -- \
//!         [--n 24] [--clients 8] [--batch 4] [--scenario agentic]`

use std::net::TcpListener;
use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Instant;

use quasar::bench::{BenchCtx, BenchReport};
use quasar::coordinator::{ClusterConfig, ClusterHandle, DispatchPolicy, EngineConfig,
                          EngineHandle, GovernorConfig};
use quasar::server::{serve, Client, ServeHandle};
use quasar::util::cli::Cli;
use quasar::workload::{ScenarioKind, ScenarioPlan};
use quasar::util::hist::Histogram;
use quasar::util::rng::Pcg;
use quasar::util::json::Json;

/// Order-independent FNV-1a over one request's `(work index, tokens)`. The
/// driver XORs these across requests into a run checksum: greedy outputs
/// per prompt are deterministic, so a warm (prefix-cached) and a cold run
/// must print the same value — CI's bit-identity gate.
fn fnv_request(idx: usize, tokens: &[i64]) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    let eat = |h: &mut u64, x: u64| {
        for b in x.to_le_bytes() {
            *h ^= b as u64;
            *h = h.wrapping_mul(0x100000001b3);
        }
    };
    eat(&mut h, idx as u64);
    for &t in tokens {
        eat(&mut h, t as u64);
    }
    h
}

fn main() {
    quasar::util::bigstack::run(|| {
        if let Err(e) = run() {
            eprintln!("error: {e:#}");
            std::process::exit(1);
        }
    })
}

/// Per-client tallies, merged by the driver after the joins.
#[derive(Default)]
struct ClientTally {
    lat: Histogram,
    ttft: Histogram,
    /// Per-request time-per-output-token: `(latency - ttft) / (tokens - 1)`.
    tpot: Histogram,
    /// Per-stage latency attribution from the server's opt-in breakdown
    /// (the six stages partition each response's latency_s).
    stage_queue: Histogram,
    stage_dispatch: Histogram,
    stage_splice: Histogram,
    stage_prefill: Histogram,
    stage_decode: Histogram,
    stage_emit: Histogram,
    /// Worst relative error of sum(stages) vs latency_s over this client's
    /// requests — CI gates it under 5%.
    stage_err_max: f64,
    /// Requests meeting the TTFT / TPOT SLO targets (attainment numerators).
    slo_ttft_ok: usize,
    slo_tpot_ok: usize,
    tokens: u64,
    l_sum: f64,
    done: usize,
    /// XOR of per-request `(index, tokens)` hashes (order-independent).
    checksum: u64,
}

fn run() -> anyhow::Result<()> {
    let args = Cli::new("serve_benchmark", "end-to-end batched serving driver")
        .opt("n", Some("24"), "number of requests")
        .opt("clients", Some("8"), "concurrent closed-loop client connections")
        .opt("batch", Some("4"), "batch bucket")
        .opt("max-new", Some("48"), "tokens per request")
        .opt("temp", Some("0"), "sampling temperature")
        .opt("method", Some("both"), "ngram | quasar | both")
        .opt("turns", Some("1"), "closed-loop turns per work item: turn k+1 resubmits the \
                                  full transcript (prompt + answer) as a longer prompt \
                                  (scenarios with an intrinsic turn count take the max)")
        .opt("scenario", Some("mixed"),
             "workload scenario: mixed | agentic | diurnal | longctx | thrash")
        .opt("rate", Some("8"), "open-loop base arrival rate for trace scenarios (req/s)")
        .opt("adaptive-gamma", Some("on"),
             "per-class adaptive draft depth: on (default; learned per task class) | \
              off (the engine's gamma cap is the fixed depth)")
        .opt("slo-ttft-ms", Some("500"), "TTFT SLO target (ms) for attainment scoring")
        .opt("slo-tpot-ms", Some("50"), "TPOT SLO target (ms) for attainment scoring")
        .flag("governor", "adaptive precision: audit w8a8 verification, demote to fp32 on drift")
        .flag("prefix-share", "shared-prefix workload: per-task system-prompt templates")
        .flag("no-prefix-cache", "disable shared-prefix KV reuse (cold-admission baseline)")
        .opt("page-tokens", Some("16"), "prefix-cache pool page size (tokens)")
        .flag("no-mid-stream", "disable mid-stream snapshots (prompt-only caching baseline)")
        .flag("warmup", "pre-populate the prefix cache from the shared-prefix templates \
                         before the first client")
        .flag("no-paged-rows", "copy-based slab batch rows (the A/B reference the paged \
                                page-table backend is compared against)")
        .flag("no-chunked-prefill", "monolithic admission prefill (the A/B reference the \
                                     chunked rider path is compared against)")
        .opt("replicas", Some("1"), "engine replicas behind the locality dispatcher \
                                     (0 = bare EngineHandle, the dispatcher-free A/B control)")
        .opt("dispatch", Some("locality"), "replica dispatch policy: locality | random")
        .opt("steal-threshold", Some("8"), "home-replica queue depth at which requests \
                                            spill to the shallowest replica")
        .opt("bench-json", None, "directory to write a machine-readable \
                                  BENCH_<method>.json artifact into")
        .flag("trace", "arm the flight recorder (per-request span events; see crate::trace)")
        .opt("trace-out", None, "directory to write the Chrome trace-event artifact \
                                 TRACE_<scenario>.json into (implies --trace)")
        .opt("slow-log-ms", None, "log a structured [slow] exemplar line for requests over \
                                   this latency (rate-limited to 1/s)")
        .parse_env();
    let n = args.usize("n");
    let clients = args.usize("clients").max(1);
    let batch = args.usize("batch");
    let max_new = args.usize("max-new");
    let temp = args.f64("temp");
    let turns = args.usize("turns").max(1);
    let page_tokens = args.usize("page-tokens").max(1);
    let scenario_name = args.str("scenario");
    let rate = args.f64("rate");
    let adaptive_gamma = match args.str("adaptive-gamma").as_str() {
        "on" => true,
        "off" => false,
        other => anyhow::bail!("unknown --adaptive-gamma {other} (on|off)"),
    };
    let slo_ttft_s = args.f64("slo-ttft-ms") / 1e3;
    let slo_tpot_s = args.f64("slo-tpot-ms") / 1e3;
    let method = args.str("method");
    let governor = args.has("governor");
    let prefix_share = args.has("prefix-share");
    let no_prefix_cache = args.has("no-prefix-cache");
    let no_mid_stream = args.has("no-mid-stream");
    let warmup = args.has("warmup");
    let no_paged_rows = args.has("no-paged-rows");
    let no_chunked_prefill = args.has("no-chunked-prefill");
    let replicas = args.usize("replicas");
    let dispatch = args.str("dispatch");
    let steal_threshold = args.usize("steal-threshold").max(1);
    let bench_json = args.get("bench-json").map(PathBuf::from);
    let trace_out = args.get("trace-out").map(PathBuf::from);
    let trace_on = args.has("trace") || trace_out.is_some();
    let slow_log_ms: Option<f64> = args
        .get("slow-log-ms")
        .map(|s| s.parse::<f64>())
        .transpose()?;

    // xla_extension tolerates exactly one PJRT client per process, so the
    // two-method comparison re-execs this binary once per method.
    if method == "both" {
        let exe = std::env::current_exe()?;
        for m in ["ngram", "quasar"] {
            let mut argv: Vec<String> = ["--method", m, "--n", &n.to_string(),
                   "--clients", &clients.to_string(),
                   "--batch", &batch.to_string(),
                   "--max-new", &max_new.to_string(),
                   "--temp", &temp.to_string(),
                   "--turns", &turns.to_string(),
                   "--scenario", &scenario_name,
                   "--rate", &rate.to_string(),
                   "--adaptive-gamma", if adaptive_gamma { "on" } else { "off" },
                   "--slo-ttft-ms", &(slo_ttft_s * 1e3).to_string(),
                   "--slo-tpot-ms", &(slo_tpot_s * 1e3).to_string(),
                   "--page-tokens", &page_tokens.to_string(),
                   "--replicas", &replicas.to_string(),
                   "--dispatch", &dispatch,
                   "--steal-threshold", &steal_threshold.to_string()]
                .iter()
                .map(|s| s.to_string())
                .collect();
            if governor {
                argv.push("--governor".into());
            }
            if prefix_share {
                argv.push("--prefix-share".into());
            }
            if no_prefix_cache {
                argv.push("--no-prefix-cache".into());
            }
            if no_mid_stream {
                argv.push("--no-mid-stream".into());
            }
            if warmup {
                argv.push("--warmup".into());
            }
            if no_paged_rows {
                argv.push("--no-paged-rows".into());
            }
            if no_chunked_prefill {
                argv.push("--no-chunked-prefill".into());
            }
            if let Some(dir) = &bench_json {
                argv.push("--bench-json".into());
                argv.push(dir.display().to_string());
            }
            if trace_on {
                argv.push("--trace".into());
            }
            if let Some(dir) = &trace_out {
                argv.push("--trace-out".into());
                argv.push(dir.display().to_string());
            }
            if let Some(ms) = slow_log_ms {
                argv.push("--slow-log-ms".into());
                argv.push(ms.to_string());
            }
            let status = std::process::Command::new(&exe).args(&argv).status()?;
            anyhow::ensure!(status.success(), "{m} run failed");
        }
        println!("\n(CPU wall includes one-time artifact compilation; the \
                  modeled-device comparison lives in `cargo bench`.)");
        return Ok(());
    }

    let ctx = BenchCtx::load()?;
    // Family templates half the prefill window long: enough shared tokens
    // for the cache to matter, enough suffix to stay distinct.
    let plen = ctx.manifest.model("qwen3-like")?.cfg.prefill_len / 2;
    let kind = ScenarioKind::parse(&scenario_name)?;
    let plan = if prefix_share {
        // Legacy flag: the shared-prefix item set as a single-turn closed
        // loop — CI's warm-vs-cold checksum legs depend on this exact shape.
        ScenarioPlan {
            kind,
            items: ctx.workloads.shared_prefix(n, plen, &mut Pcg::seeded(0xE2E))?,
            arrivals: Vec::new(),
            turns: 1,
        }
    } else {
        ctx.workloads.scenario(kind, n, plen, rate, &mut Pcg::seeded(0xE2E))?
    };
    // Scenarios with an intrinsic turn structure (agentic) raise the turn
    // count; an explicit larger --turns still wins.
    let turns = turns.max(plan.turns);
    let items = plan.items;
    // Open-loop pacing: offset seconds from run start per conversation;
    // empty = closed loop (each client fires as soon as it is free).
    let arrivals: Arc<Vec<f64>> = Arc::new(plan.arrivals);
    // The wire protocol takes prompt text; the closed-lexicon tokenizer
    // round-trips decode(encode(text)) exactly.
    let prompts: Arc<Vec<(String, String)>> = Arc::new(
        items
            .iter()
            .map(|it| (ctx.tok.decode(&it.prompt_ids), it.task.clone()))
            .collect(),
    );
    let artifacts = std::env::var("QUASAR_ARTIFACTS")
        .unwrap_or_else(|_| "artifacts".into());

    let (name, mut cfg) = match method.as_str() {
        "ngram" => ("ngram/fp32 (baseline)", EngineConfig::ngram(batch, 5)),
        "quasar" => ("quasar/w8a8", EngineConfig::quasar(batch, 5)),
        other => anyhow::bail!("unknown --method {other}"),
    };
    if governor {
        // Inert for ngram (primary already is the fp32 reference); for
        // quasar it audits w8a8 verification online and demotes drifting
        // request classes to fp32.
        cfg.governor = GovernorConfig::on();
    }
    cfg.prefix.enabled = !no_prefix_cache;
    cfg.prefix.mid_stream = !no_mid_stream;
    cfg.prefix.page_tokens = page_tokens;
    cfg.paged_rows = !no_paged_rows;
    cfg.chunked_prefill = !no_chunked_prefill;
    cfg.adaptive_gamma = adaptive_gamma;
    cfg.trace = trace_on;
    let policy = DispatchPolicy::parse(&dispatch)
        .ok_or_else(|| anyhow::anyhow!("unknown --dispatch {dispatch} (locality|random)"))?;
    let max_queue = 4 * (n * turns).max(1);
    // --replicas 0 drives a bare EngineHandle with no dispatch plane at all
    // — the differential control the 1-replica cluster must match bit for
    // bit; --replicas N>=1 goes through the cluster dispatcher.
    let handle: ServeHandle = if replicas == 0 {
        EngineHandle::spawn(artifacts.clone().into(), "qwen3-like".into(), cfg, max_queue)?
            .into()
    } else {
        let ccfg = ClusterConfig {
            replicas,
            dispatch: policy,
            steal_threshold,
            ..ClusterConfig::default()
        };
        ClusterHandle::spawn(artifacts.clone().into(), "qwen3-like".into(), cfg, ccfg,
                             max_queue)?
            .into()
    };
    // Boot warm-up: cache the per-family templates before any client
    // connects, so the first request of each family already admits warm.
    if warmup {
        if prefix_share && !no_prefix_cache {
            let plen = ctx.manifest.model("qwen3-like")?.cfg.prefill_len / 2;
            let templates: Vec<(Vec<i32>, String)> = ctx
                .workloads
                .templates(plen)?
                .into_iter()
                .map(|(task, ids)| (ids, task))
                .collect();
            let cached = handle.warm_prefix(templates)?;
            println!("warm-up cached {cached} family templates");
        } else {
            eprintln!("[warn] --warmup needs --prefix-share and an enabled cache; skipping");
        }
    }
    let listener = TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?;
    let tok_srv = ctx.tok.clone();
    let server = std::thread::spawn(move || serve(listener, handle, tok_srv, clients + 2));

    // Closed loop: each client connection immediately issues the next
    // request from the shared work list when its previous one completes,
    // keeping the scheduler fed so the batch can fill.
    let next = Arc::new(AtomicUsize::new(0));
    // Slow-request exemplar gate shared by every client: at most one
    // structured [slow] line per second across the whole run.
    let slow_gate: Arc<std::sync::Mutex<Option<Instant>>> =
        Arc::new(std::sync::Mutex::new(None));
    let t0 = Instant::now();
    let mut joins = Vec::new();
    for _ in 0..clients {
        let next = Arc::clone(&next);
        let prompts = Arc::clone(&prompts);
        let slow_gate = Arc::clone(&slow_gate);
        let arrivals = Arc::clone(&arrivals);
        let addr = addr.to_string();
        joins.push(std::thread::spawn(move || -> anyhow::Result<ClientTally> {
            let mut client = Client::connect(&addr)?;
            let mut tally = ClientTally::default();
            loop {
                let i = next.fetch_add(1, Ordering::SeqCst);
                if i >= prompts.len() {
                    return Ok(tally);
                }
                // Open-loop trace replay: hold this conversation until its
                // recorded arrival offset. Indices are claimed in order and
                // the offsets are sorted, so the pool reproduces the trace's
                // bursts as long as enough clients are free.
                if let Some(&at) = arrivals.get(i) {
                    let lag = at - t0.elapsed().as_secs_f64();
                    if lag > 0.0 {
                        std::thread::sleep(std::time::Duration::from_secs_f64(lag));
                    }
                }
                let (text, task) = &prompts[i];
                // Multi-turn closed loop: turn k+1's prompt is turn k's
                // full transcript (prompt + answer + a continuation mark).
                // Greedy answers are deterministic, so warm and cold runs
                // build identical follow-up prompts and the run checksum
                // stays comparable — while mid-stream snapshots let the
                // warm engine admit each follow-up past the whole
                // transcript instead of just the original prompt.
                let mut text = text.clone();
                for turn in 0..turns {
                    let sent = Instant::now();
                    let resp = client.roundtrip(&Json::obj(vec![
                        ("prompt", Json::str(text.clone())),
                        ("max_new", Json::num(max_new as f64)),
                        ("temp", Json::num(temp)),
                        ("task", Json::str(task.clone())),
                        ("stages", Json::Bool(true)),
                    ]))?;
                    let roundtrip_s = sent.elapsed().as_secs_f64();
                    anyhow::ensure!(resp.opt("error").is_none(), "server error: {resp}");
                    let lat_s = resp.get("latency_s")?.as_f64()?;
                    let ttft_s = resp.get("ttft_s")?.as_f64()?;
                    tally.lat.record(lat_s);
                    // Per-stage attribution: the six stages must partition
                    // the reported latency (CI gates the worst rel. error).
                    let st = resp.get("stages")?;
                    let queue_s = st.get("queue_s")?.as_f64()?;
                    let dispatch_s = st.get("dispatch_s")?.as_f64()?;
                    let splice_s = st.get("splice_s")?.as_f64()?;
                    let prefill_s = st.get("prefill_s")?.as_f64()?;
                    let decode_s = st.get("decode_s")?.as_f64()?;
                    let emit_s = st.get("emit_s")?.as_f64()?;
                    tally.stage_queue.record(queue_s);
                    tally.stage_dispatch.record(dispatch_s);
                    tally.stage_splice.record(splice_s);
                    tally.stage_prefill.record(prefill_s);
                    tally.stage_decode.record(decode_s);
                    tally.stage_emit.record(emit_s);
                    let stage_sum =
                        queue_s + dispatch_s + splice_s + prefill_s + decode_s + emit_s;
                    if lat_s > 1e-9 {
                        tally.stage_err_max =
                            tally.stage_err_max.max((stage_sum - lat_s).abs() / lat_s);
                    }
                    if let Some(thresh_ms) = slow_log_ms {
                        if lat_s * 1e3 >= thresh_ms {
                            let now = Instant::now();
                            let mut gate = slow_gate.lock().unwrap();
                            let open = gate
                                .map_or(true, |t| now.duration_since(t).as_secs_f64() >= 1.0);
                            if open {
                                *gate = Some(now);
                                eprintln!(
                                    "[slow] ticket={} task={} lat_ms={:.1} queue_ms={:.1} \
                                     dispatch_ms={:.1} splice_ms={:.1} prefill_ms={:.1} \
                                     decode_ms={:.1} emit_ms={:.1} replica={} stolen={}",
                                    resp.get("id")?.as_i64()?,
                                    task,
                                    lat_s * 1e3,
                                    queue_s * 1e3,
                                    dispatch_s * 1e3,
                                    splice_s * 1e3,
                                    prefill_s * 1e3,
                                    decode_s * 1e3,
                                    emit_s * 1e3,
                                    resp.get("replica")?.as_i64()?,
                                    resp.get("stolen")?.as_bool()?,
                                );
                            }
                        }
                    }
                    // TTFT from the client's own submit instant: the server
                    // value starts at the engine's `submitted_at` and so
                    // misses transport + dispatch before the request reaches
                    // the engine thread. Subtract the post-first-token
                    // generation time from the observed roundtrip instead.
                    let client_ttft_s = (roundtrip_s - (lat_s - ttft_s)).max(0.0);
                    tally.ttft.record(client_ttft_s);
                    let toks: Vec<i64> = resp
                        .get("tokens")?
                        .as_arr()?
                        .iter()
                        .map(|t| t.as_i64())
                        .collect::<Result<_, _>>()?;
                    let tpot_s = (lat_s - ttft_s).max(0.0)
                        / toks.len().saturating_sub(1).max(1) as f64;
                    tally.tpot.record(tpot_s);
                    if client_ttft_s <= slo_ttft_s {
                        tally.slo_ttft_ok += 1;
                    }
                    if tpot_s <= slo_tpot_s {
                        tally.slo_tpot_ok += 1;
                    }
                    tally.checksum ^= fnv_request(i * turns + turn, &toks);
                    tally.tokens += toks.len() as u64;
                    tally.l_sum += resp.get("accept_len")?.as_f64()?;
                    tally.done += 1;
                    if turn + 1 < turns {
                        let answer = resp.get("text")?.as_str()?;
                        text = format!("{text} {answer} .").trim().to_string();
                    }
                }
            }
        }));
    }
    let mut total = ClientTally::default();
    for j in joins {
        let t = j.join().expect("client thread panicked")?;
        total.lat.merge(&t.lat);
        total.ttft.merge(&t.ttft);
        total.tpot.merge(&t.tpot);
        total.stage_queue.merge(&t.stage_queue);
        total.stage_dispatch.merge(&t.stage_dispatch);
        total.stage_splice.merge(&t.stage_splice);
        total.stage_prefill.merge(&t.stage_prefill);
        total.stage_decode.merge(&t.stage_decode);
        total.stage_emit.merge(&t.stage_emit);
        total.stage_err_max = total.stage_err_max.max(t.stage_err_max);
        total.slo_ttft_ok += t.slo_ttft_ok;
        total.slo_tpot_ok += t.slo_tpot_ok;
        total.tokens += t.tokens;
        total.l_sum += t.l_sum;
        total.done += t.done;
        total.checksum ^= t.checksum;
    }
    let wall = t0.elapsed().as_secs_f64();
    anyhow::ensure!(
        total.done == n * turns,
        "completed {}/{} requests", total.done, n * turns
    );

    let scenario = format!(
        "{method}{}{}{}{}{}",
        if scenario_name != "mixed" { format!("_{scenario_name}") } else { String::new() },
        if !adaptive_gamma { "_static" } else { "" },
        if no_paged_rows { "_copyrows" } else { "" },
        if no_chunked_prefill { "_monoprefill" } else { "" },
        match replicas {
            1 => String::new(),
            0 => "_bare".into(),
            r => format!("_r{r}"),
        }
    );
    let mut ctl = Client::connect(&addr.to_string())?;
    let stats = ctl.stats()?;
    // Drain the flight recorder through the wire protocol and persist the
    // Chrome trace-event artifact before the server shuts down.
    if let Some(dir) = &trace_out {
        let trace = ctl.roundtrip(&Json::obj(vec![("cmd", Json::str("trace"))]))?;
        std::fs::create_dir_all(dir)?;
        let path = dir.join(format!("TRACE_{scenario}.json"));
        std::fs::write(&path, format!("{trace}\n"))?;
        println!("trace_json={}", path.display());
    }
    ctl.shutdown()?;
    server.join().expect("server thread panicked")?;

    println!(
        "\n=== {name} [{scenario_name}]: {n} requests x {turns} turn(s), {clients} clients, \
         b={batch}, T={temp} ==="
    );
    println!("  wall                {wall:.1}s  ({:.1} tok/s CPU)",
             total.tokens as f64 / wall);
    println!("  tokens generated    {}", total.tokens);
    println!("  mean acceptance L   {:.2}", total.l_sum / total.done.max(1) as f64);
    println!("  batch occupancy     {:.2} rows/step (cap {}) over {} steps",
             stats.get("batch_occupancy")?.as_f64()?,
             stats.get("batch")?.as_i64()?,
             stats.get("steps")?.as_i64()?);
    println!("  chunk efficiency    {:.2} useful/executed positions",
             stats.get("chunk_efficiency")?.as_f64()?);
    println!("  sub-batches/step    {:.2}",
             stats.get("subbatches_per_step")?.as_f64()?);
    for b in stats.get("buckets")?.as_arr()? {
        println!("  bucket b{:<2}          {} calls, {:.2} rows/call",
                 b.get("bucket")?.as_i64()?,
                 b.get("calls")?.as_i64()?,
                 b.get("mean_rows")?.as_f64()?);
    }
    for v in stats.get("variants")?.as_arr()? {
        println!("  variant {:<12}{} calls",
                 v.get("variant")?.as_str()?,
                 v.get("calls")?.as_i64()?);
    }
    if governor {
        let gov = stats.get("governor")?;
        println!("  governor            {} audits ({:.0}% of eligible), top-1 agreement {:.3}, \
                  accept delta {:+.3}",
                 gov.get("audits")?.as_i64()?,
                 gov.get("audit_rate")?.as_f64()? * 100.0,
                 gov.get("top1_agreement")?.as_f64()?,
                 gov.get("accept_delta")?.as_f64()?);
        println!("                      {} probes, demotions {}, promotions {}",
                 gov.get("probes")?.as_i64()?,
                 gov.get("demotions")?.as_i64()?,
                 gov.get("promotions")?.as_i64()?);
    }
    // Per-class draft-depth controller: the accept EWMA each class has
    // learned and the drafted/accepted volume behind it.
    let gamma = stats.get("gamma")?;
    let gamma_classes = gamma.get("classes")?.as_arr()?;
    println!("  gamma controller    {} ({} classes), {} drafted / {} accepted over {} steps",
             if adaptive_gamma { "adaptive" } else { "static (off)" },
             gamma_classes.len(),
             gamma.get("drafted")?.as_i64()?,
             gamma.get("accepted")?.as_i64()?,
             gamma.get("steps")?.as_i64()?);
    for c in gamma_classes {
        println!("    class {:<14} accept ewma {:.2} over {} steps",
                 c.get("class")?.as_str()?,
                 c.get("accept_ewma")?.as_f64()?,
                 c.get("steps")?.as_i64()?);
    }
    let prefix = stats.get("prefix")?;
    let hit_rate = prefix.get("hit_rate")?.as_f64()?;
    println!("  prefix cache        {} hits / {} misses (rate {:.2}), {} hit tokens \
              ({} mid-stream)",
             prefix.get("hits")?.as_i64()?,
             prefix.get("misses")?.as_i64()?,
             hit_rate,
             prefix.get("hit_tokens")?.as_i64()?,
             prefix.get("mid_stream_hit_tokens")?.as_i64()?);
    println!("                      {:.1} MiB resident in {} pages / {} runs \
              (share ratio {:.2}), {} evictions",
             prefix.get("resident_bytes")?.as_f64()? / (1u64 << 20) as f64,
             prefix.get("resident_pages")?.as_i64()?,
             prefix.get("segments")?.as_i64()?,
             prefix.get("page_share_ratio")?.as_f64()?,
             prefix.get("evictions")?.as_i64()?);
    let kv = stats.get("kv")?;
    let paged = kv.get("paged_rows")?.as_bool()?;
    let mib = (1u64 << 20) as f64;
    println!("  kv rows             {} backend, {:.1} MiB resident (peak {:.1} MiB)",
             if paged { "paged" } else { "copy" },
             kv.get("resident_bytes")?.as_f64()? / mib,
             kv.get("resident_peak_bytes")?.as_f64()? / mib);
    println!("                      {} shared / {} copied pages, {} tail copies, \
              {:.4}s copy saved ({:.4}s prefill saved)",
             kv.get("row_shared_pages")?.as_i64()?,
             kv.get("row_copied_pages")?.as_i64()?,
             kv.get("row_tail_copies")?.as_i64()?,
             kv.get("copy_saved_s")?.as_f64()?,
             prefix.get("prefill_saved_s")?.as_f64()?);
    let pf = stats.get("prefill")?;
    println!("  prefill             {} mode, {} chunks, {} decode-stall steps, \
              {:.4}s stall saved",
             if no_chunked_prefill { "monolithic" } else { "chunked" },
             pf.get("chunks")?.as_i64()?,
             pf.get("decode_stall_steps")?.as_i64()?,
             pf.get("stall_saved_s")?.as_f64()?);
    println!("                      ttft warm p50/p99 {:.1}/{:.1}ms cold {:.1}/{:.1}ms, \
              tpot warm p99 {:.2}ms cold {:.2}ms",
             pf.get("ttft_warm_p50_s")?.as_f64()? * 1e3,
             pf.get("ttft_warm_p99_s")?.as_f64()? * 1e3,
             pf.get("ttft_cold_p50_s")?.as_f64()? * 1e3,
             pf.get("ttft_cold_p99_s")?.as_f64()? * 1e3,
             pf.get("tpot_warm_p99_s")?.as_f64()? * 1e3,
             pf.get("tpot_cold_p99_s")?.as_f64()? * 1e3);
    let truncated = stats.get("prompt_truncated")?.as_i64()?;
    if truncated > 0 {
        println!("  prompts truncated   {truncated}");
    }
    println!("  sched delay (mean)  {:.1}ms",
             stats.get("sched_delay_s")?.as_f64()? * 1e3);
    println!("  request latency     {}", total.lat.summary_ms());
    println!("  ttft                {}", total.ttft.summary_ms());
    println!("  tpot                {}", total.tpot.summary_ms());
    let slo_ttft_attainment = total.slo_ttft_ok as f64 / total.done.max(1) as f64;
    let slo_tpot_attainment = total.slo_tpot_ok as f64 / total.done.max(1) as f64;
    println!("  slo attainment      ttft<= {:.0}ms: {:.1}%   tpot<= {:.0}ms: {:.1}%",
             slo_ttft_s * 1e3, slo_ttft_attainment * 100.0,
             slo_tpot_s * 1e3, slo_tpot_attainment * 100.0);
    // Per-request stage attribution (from the opt-in "stages" wire field):
    // the six stages partition each request's observed latency, so their
    // sums must track latency_s to within float noise plus clock skew.
    println!("  stage queue         {}", total.stage_queue.summary_ms());
    println!("  stage dispatch      {}", total.stage_dispatch.summary_ms());
    println!("  stage splice        {}", total.stage_splice.summary_ms());
    println!("  stage prefill       {}", total.stage_prefill.summary_ms());
    println!("  stage decode        {}", total.stage_decode.summary_ms());
    println!("  stage emit          {}", total.stage_emit.summary_ms());
    println!("  stage sum error     {:.4}% (worst request)", total.stage_err_max * 100.0);
    // Machine-readable lines for the CI warm-vs-cold and paged-vs-copy
    // smokes: identical checksums across cache-on/cache-off (and paged/copy)
    // runs prove bit-identity; a non-zero hit rate proves the warm run
    // actually reused prefixes; the mid-stream token count proves multi-turn
    // resubmits hit past their original prompts; the peak-resident and
    // copied-page counters gate the zero-copy claims.
    println!("output_checksum={:016x}", total.checksum);
    println!("prefix_hit_rate={hit_rate:.4}");
    println!(
        "prefix_mid_stream_hit_tokens={}",
        prefix.get("mid_stream_hit_tokens")?.as_i64()?
    );
    println!("paged_rows={}", paged as u8);
    println!(
        "kv_resident_peak_bytes={}",
        kv.get("resident_peak_bytes")?.as_i64()?
    );
    println!(
        "kv_row_copied_pages={}",
        kv.get("row_copied_pages")?.as_i64()?
    );
    // Chunked-prefill A/B gates: the chunked run must keep the identical
    // checksum while running strictly fewer decode-stall steps and booking
    // a positive modeled stall saving.
    println!("chunked_prefill={}", !no_chunked_prefill as u8);
    println!("prefill_chunks={}", pf.get("chunks")?.as_i64()?);
    println!(
        "decode_stall_steps={}",
        pf.get("decode_stall_steps")?.as_i64()?
    );
    println!(
        "prefill_stall_saved_s={:.6}",
        pf.get("stall_saved_s")?.as_f64()?
    );
    println!("ttft_p50_s={:.6}", total.ttft.p50());
    println!("ttft_p99_s={:.6}", total.ttft.p99());
    println!("tpot_p99_s={:.6}", total.tpot.p99());
    // Scenario/SLO gates: the suite smoke asserts the attainment fields
    // exist and parse; the controller A/B legs compare output_checksum
    // across adaptive on/off (lossless — depth policy never moves outputs)
    // and drafted volume (the controller's actual lever).
    println!("scenario={scenario_name}");
    println!("adaptive_gamma={}", adaptive_gamma as u8);
    println!("slo_ttft_attainment={slo_ttft_attainment:.4}");
    println!("slo_tpot_attainment={slo_tpot_attainment:.4}");
    println!("gamma_drafted={}", gamma.get("drafted")?.as_i64()?);
    println!("gamma_accepted={}", gamma.get("accepted")?.as_i64()?);
    // Stage-attribution gate: the CI trace smoke requires the six per-stage
    // durations to reconstruct each request's latency within 5%.
    println!("stage_sum_max_rel_err={:.6}", total.stage_err_max);
    // Multi-replica A/B gates: equal checksums across --replicas 0 (bare
    // engine), 1 and N prove the dispatch plane never changes outputs; the
    // locality leg's warm hit rate must beat the --dispatch random control
    // while steals stay bounded by the threshold rule.
    println!("replicas={replicas}");
    let dispatch_stats = if replicas >= 1 { Some(stats.get("dispatch")?) } else { None };
    match &dispatch_stats {
        Some(d) => {
            println!("dispatch={}", d.get("policy")?.as_str()?);
            println!("steal_count={}", d.get("steals")?.as_i64()?);
            println!("locality_hit_rate={:.4}", d.get("locality_hit_rate")?.as_f64()?);
        }
        None => {
            println!("dispatch=none");
            println!("steal_count=0");
            println!("locality_hit_rate=0.0000");
        }
    }

    if let Some(dir) = &bench_json {
        let mut r = BenchReport::new(&scenario);
        r.text("method", &method)
            .text("workload_scenario", &scenario_name)
            .flag("paged_rows", paged)
            .flag("chunked_prefill", !no_chunked_prefill)
            .flag("adaptive_gamma", adaptive_gamma)
            .num("slo_ttft_s", slo_ttft_s)
            .num("slo_tpot_s", slo_tpot_s)
            .num("slo_ttft_attainment", slo_ttft_attainment)
            .num("slo_tpot_attainment", slo_tpot_attainment)
            .num("requests", (n * turns) as f64)
            .num("clients", clients as f64)
            .num("batch", batch as f64)
            .num("turns", turns as f64)
            .num("wall_s", wall)
            .num("tokens", total.tokens as f64)
            .num("throughput_tok_s", total.tokens as f64 / wall.max(1e-12))
            .num("mean_accept_len", total.l_sum / total.done.max(1) as f64)
            .num("latency_p50_s", total.lat.p50())
            .num("latency_p95_s", total.lat.p95())
            .num("ttft_p50_s", total.ttft.p50())
            .num("ttft_p95_s", total.ttft.p95())
            .num("ttft_p99_s", total.ttft.p99())
            .num("tpot_p50_s", total.tpot.p50())
            .num("tpot_p95_s", total.tpot.p95())
            .num("tpot_p99_s", total.tpot.p99())
            .num("stage_queue_p50_s", total.stage_queue.p50())
            .num("stage_queue_p99_s", total.stage_queue.p99())
            .num("stage_dispatch_p50_s", total.stage_dispatch.p50())
            .num("stage_dispatch_p99_s", total.stage_dispatch.p99())
            .num("stage_splice_p50_s", total.stage_splice.p50())
            .num("stage_splice_p99_s", total.stage_splice.p99())
            .num("stage_prefill_p50_s", total.stage_prefill.p50())
            .num("stage_prefill_p99_s", total.stage_prefill.p99())
            .num("stage_decode_p50_s", total.stage_decode.p50())
            .num("stage_decode_p99_s", total.stage_decode.p99())
            .num("stage_emit_p50_s", total.stage_emit.p50())
            .num("stage_emit_p99_s", total.stage_emit.p99())
            .num("stage_sum_max_rel_err", total.stage_err_max)
            .num("chunk_efficiency", stats.get("chunk_efficiency")?.as_f64()?)
            .num("batch_occupancy", stats.get("batch_occupancy")?.as_f64()?)
            .num("prefix_hit_rate", hit_rate)
            .num(
                "prefix_mid_stream_hit_tokens",
                prefix.get("mid_stream_hit_tokens")?.as_f64()?,
            )
            .num(
                "prefix_resident_pages",
                prefix.get("resident_pages")?.as_f64()?,
            )
            .num(
                "prefill_saved_s",
                prefix.get("prefill_saved_s")?.as_f64()?,
            )
            .num("kv_resident_bytes", kv.get("resident_bytes")?.as_f64()?)
            .num(
                "kv_resident_peak_bytes",
                kv.get("resident_peak_bytes")?.as_f64()?,
            )
            .num(
                "kv_row_shared_pages",
                kv.get("row_shared_pages")?.as_f64()?,
            )
            .num(
                "kv_row_copied_pages",
                kv.get("row_copied_pages")?.as_f64()?,
            )
            .num(
                "kv_row_tail_copies",
                kv.get("row_tail_copies")?.as_f64()?,
            )
            .num("kv_copy_saved_s", kv.get("copy_saved_s")?.as_f64()?)
            .num("prefill_chunks", pf.get("chunks")?.as_f64()?)
            .num(
                "decode_stall_steps",
                pf.get("decode_stall_steps")?.as_f64()?,
            )
            .num(
                "prefill_stall_saved_s",
                pf.get("stall_saved_s")?.as_f64()?,
            )
            .num("ttft_warm_p50_s", pf.get("ttft_warm_p50_s")?.as_f64()?)
            .num("ttft_warm_p99_s", pf.get("ttft_warm_p99_s")?.as_f64()?)
            .num("ttft_cold_p50_s", pf.get("ttft_cold_p50_s")?.as_f64()?)
            .num("ttft_cold_p99_s", pf.get("ttft_cold_p99_s")?.as_f64()?)
            .num("tpot_warm_p99_s", pf.get("tpot_warm_p99_s")?.as_f64()?)
            .num("tpot_cold_p99_s", pf.get("tpot_cold_p99_s")?.as_f64()?)
            .text("output_checksum", &format!("{:016x}", total.checksum));
        r.num("replica_count", replicas as f64);
        // Per-class gamma controller state straight from the fleet stats.
        r.json("gamma", gamma.clone());
        if let Some(d) = &dispatch_stats {
            // Per-replica breakdown straight from the fleet stats: shows
            // whether dispatch kept the replicas busy (occupancy), balanced
            // (dispatched/queue depth) and warm (per-replica hit rate).
            let mut reps = Vec::new();
            for (ri, rs) in stats.get("replicas")?.as_arr()?.iter().enumerate() {
                reps.push(Json::obj(vec![
                    ("replica", Json::num(ri as f64)),
                    ("completed", rs.get("completed")?.clone()),
                    ("steps", rs.get("steps")?.clone()),
                    ("batch_occupancy", rs.get("batch_occupancy")?.clone()),
                    ("queue_depth", rs.get("queue_depth")?.clone()),
                    ("dispatched", d.get("dispatched")?.as_arr()?[ri].clone()),
                    (
                        "throughput_req_s",
                        Json::num(rs.get("completed")?.as_f64()? / wall.max(1e-12)),
                    ),
                    ("prefix_hit_rate", rs.get("prefix")?.get("hit_rate")?.clone()),
                ]));
            }
            r.json("replicas", Json::arr(reps));
            r.json("dispatch", (*d).clone());
        }
        let path = r.write_to(dir)?;
        println!("bench_json={}", path.display());
    }
    Ok(())
}

//! End-to-end serving validation (DESIGN.md E8): load the trained model,
//! serve a mixed-task batched workload through the full stack (router ->
//! engine thread -> continuous batcher -> drafter -> PJRT verification),
//! and report latency / throughput / acceptance — real wall-clock, plus the
//! modeled-device speedup comparison between the Ngram baseline and Quasar.
//!
//! Run: `cargo run --release --example serve_benchmark -- [--n 24] [--batch 4]`

use std::time::{Duration, Instant};

use quasar::bench::BenchCtx;
use quasar::coordinator::{EngineConfig, EngineHandle};
use quasar::util::cli::Cli;
use quasar::util::hist::Histogram;
use quasar::util::rng::Pcg;
use quasar::workload::bench_params;

fn main() {
    quasar::util::bigstack::run(|| {
        if let Err(e) = run() {
            eprintln!("error: {e:#}");
            std::process::exit(1);
        }
    })
}

fn run() -> anyhow::Result<()> {
    let args = Cli::new("serve_benchmark", "end-to-end batched serving driver")
        .opt("n", Some("24"), "number of requests")
        .opt("batch", Some("4"), "batch bucket")
        .opt("max-new", Some("48"), "tokens per request")
        .opt("temp", Some("0"), "sampling temperature")
        .opt("method", Some("both"), "ngram | quasar | both")
        .parse_env();
    let n = args.usize("n");
    let batch = args.usize("batch");
    let max_new = args.usize("max-new");
    let temp = args.f64("temp");
    let method = args.str("method");

    // xla_extension tolerates exactly one PJRT client per process, so the
    // two-method comparison re-execs this binary once per method.
    if method == "both" {
        let exe = std::env::current_exe()?;
        for m in ["ngram", "quasar"] {
            let status = std::process::Command::new(&exe)
                .args(["--method", m, "--n", &n.to_string(),
                       "--batch", &batch.to_string(),
                       "--max-new", &max_new.to_string(),
                       "--temp", &temp.to_string()])
                .status()?;
            anyhow::ensure!(status.success(), "{m} run failed");
        }
        println!("\n(CPU wall includes one-time artifact compilation; the \
                  modeled-device comparison lives in `cargo bench`.)");
        return Ok(());
    }

    let ctx = BenchCtx::load()?;
    let items = ctx.workloads.mixed(n, &mut Pcg::seeded(0xE2E));
    let artifacts = std::env::var("QUASAR_ARTIFACTS")
        .unwrap_or_else(|_| "artifacts".into());

    {
        let (name, cfg) = match method.as_str() {
            "ngram" => ("ngram/fp32 (baseline)", EngineConfig::ngram(batch, 5)),
            "quasar" => ("quasar/w8a8", EngineConfig::quasar(batch, 5)),
            other => anyhow::bail!("unknown --method {other}"),
        };
        let handle = EngineHandle::spawn(
            artifacts.clone().into(), "qwen3-like".into(), cfg, 4 * n,
        )?;
        let t0 = Instant::now();
        for it in &items {
            handle.submit(it.prompt_ids.clone(), bench_params(temp, max_new), &it.task)?;
        }
        let mut lat = Histogram::new();
        let mut ttft = Histogram::new();
        let mut tokens = 0u64;
        let mut l_sum = 0.0;
        let mut done = 0;
        while done < n {
            let Some(c) = handle.next_completion(Duration::from_secs(300)) else {
                anyhow::bail!("timed out waiting for completions ({done}/{n})");
            };
            lat.record(c.latency_s);
            ttft.record(c.ttft_s);
            tokens += c.tokens.len() as u64;
            l_sum += c.stats.mean_acceptance_len();
            done += 1;
        }
        let wall = t0.elapsed().as_secs_f64();
        println!("\n=== {name}: {n} requests, b={batch}, T={temp} ===");
        println!("  wall                {wall:.1}s  ({:.1} tok/s CPU)", tokens as f64 / wall);
        println!("  tokens generated    {tokens}");
        println!("  mean acceptance L   {:.2}", l_sum / n as f64);
        println!("  request latency     {}", lat.summary_ms());
        println!("  ttft                {}", ttft.summary_ms());
        handle.shutdown()?;
    }
    Ok(())
}

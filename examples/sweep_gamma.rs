//! Speculation-depth sweep: how L and the modeled speedup respond to gamma,
//! for both verifier variants (companion to the Table 3 bench) — plus an
//! occupancy sweep showing the elastic step planner's modeled-traffic win
//! when a batched group runs below capacity, and a fidelity-governor
//! agreement-threshold sweep showing what the online audit safety net costs
//! at each floor.
//!
//! Run: `cargo run --release --example sweep_gamma -- [--task gsm8k]`

use std::rc::Rc;

use quasar::bench::{prompts_for, run_method, speed, BenchCtx, TableWriter};
use quasar::coordinator::{
    DrafterKind, Engine, EngineConfig, FnKind, GovernorConfig, PrefixCacheConfig,
};
use quasar::spec::NgramConfig;
use quasar::util::cli::Cli;
use quasar::util::rng::Pcg;
use quasar::workload::bench_params;

fn main() {
    quasar::util::bigstack::run(|| {
        if let Err(e) = run() {
            eprintln!("error: {e:#}");
            std::process::exit(1);
        }
    })
}

fn run() -> anyhow::Result<()> {
    let args = Cli::new("sweep_gamma", "speculation depth sweep")
        .opt("task", Some("gsm8k"), "workload task family")
        .opt("n", Some("4"), "prompts")
        .opt("batch", Some("4"), "batch bucket for the occupancy sweep")
        .parse_env();
    let ctx = BenchCtx::load()?;
    let mr = ctx.model("qwen3-like")?;
    let perf = ctx.perf(&mr);
    let items = prompts_for(&ctx, &args.str("task"), args.usize("n"), 5)?;
    let base = run_method(&mr, &perf, EngineConfig::vanilla(1), &items, 0.0, 48)?;

    let mut table = TableWriter::new(
        &format!("gamma sweep on {}", args.str("task")),
        &["gamma", "ngram L", "ngram Speed", "quasar L", "quasar Speed"],
    );
    for gamma in [1usize, 2, 3, 5, 7, 9] {
        let mk = |verifier: &str| EngineConfig {
            verifier: verifier.into(),
            drafter: DrafterKind::Ngram(NgramConfig { gamma, adaptive: false, ..Default::default() }),
            batch: 1,
            gamma,
            // A depth sweep measures the depth it requests: pin both the
            // drafter's EWMA and the per-class controller off.
            adaptive_gamma: false,
            seed: 0,
            policy: Default::default(),
            elastic: true,
            governor: Default::default(),
            prefix: Default::default(),
            paged_rows: true,
            chunked_prefill: true,
            replica: 0,
            replicas: 1,
            trace: false,
        };
        let ng = run_method(&mr, &perf, mk("fp32"), &items, 0.0, 48)?;
        let qs = run_method(&mr, &perf, mk("w8a8"), &items, 0.0, 48)?;
        table.row(vec![
            gamma.to_string(),
            format!("{:.2}", ng.mean_l()), speed(ng.speedup_vs(&base)),
            format!("{:.2}", qs.mean_l()), speed(qs.speedup_vs(&base)),
        ]);
    }
    table.print();

    // ---- elastic planner vs monolithic at occupancy < batch -------------
    // Submitting fewer prompts than the bucket leaves rows idle; the
    // monolithic engine still streams every KV row of the configured bucket
    // each step, while the planner executes the smallest exported bucket
    // that fits (and splits decode-only rows out when that prices lower).
    let batch = args.usize("batch");
    let mut occ_table = TableWriter::new(
        &format!("elastic planner vs monolithic, batch bucket {batch} (modeled decode s)"),
        &["occupancy", "monolithic", "elastic", "saved"],
    );
    for occupancy in 1..=batch.min(items.len()) {
        let mk = |elastic: bool| EngineConfig {
            elastic,
            ..EngineConfig::quasar(batch, 5)
        };
        let mono = run_method(&mr, &perf, mk(false), &items[..occupancy], 0.0, 48)?;
        let ela = run_method(&mr, &perf, mk(true), &items[..occupancy], 0.0, 48)?;
        occ_table.row(vec![
            format!("{occupancy}/{batch}"),
            format!("{:.4}s", mono.modeled_s),
            format!("{:.4}s", ela.modeled_s),
            format!("{:.1}%", 100.0 * (1.0 - ela.modeled_s / mono.modeled_s.max(1e-12))),
        ]);
    }
    occ_table.print();
    println!(
        "\n(Elastic and monolithic runs commit identical greedy tokens; the\n\
         saving is modeled memory traffic on the simulated device.)"
    );

    // ---- fidelity-governor agreement-threshold sweep --------------------
    // The governor shadow-audits a sampled fraction of w8a8 verify
    // sub-batches against fp32 and demotes a request class whose top-1
    // agreement EWMA sinks below the floor. On a healthy verifier no floor
    // should trigger a demotion; the table shows what the safety net costs
    // (audit overhead inside the modeled decode time) as the floor — and
    // the audit rate backing it — tighten.
    let mut gov_table = TableWriter::new(
        "fidelity governor agreement-floor sweep (quasar, gamma 5)",
        &["floor", "audit rate", "modeled decode", "audit overhead", "audits", "demotions"],
    );
    for (floor, audit_rate) in [(0.90, 0.125), (0.95, 0.25), (0.98, 0.25), (0.995, 0.5)] {
        let cfg = EngineConfig {
            governor: GovernorConfig {
                enabled: true,
                floor,
                audit_rate,
                ..Default::default()
            },
            ..EngineConfig::quasar(1, 5)
        };
        let mut engine = Engine::new(Rc::clone(&mr), cfg)?;
        for it in &items {
            engine.submit(it.prompt_ids.clone(), bench_params(0.0, 48), &it.task);
        }
        engine.run_to_completion()?;
        gov_table.row(vec![
            format!("{floor}"),
            format!("{audit_rate}"),
            format!("{:.4}s", perf.decode_time(&engine.call_log, None)),
            format!("{:.4}s", perf.audit_time(&engine.call_log)),
            engine.call_log.calls(FnKind::Audit).to_string(),
            engine.governor().demotions.to_string(),
        ]);
    }
    gov_table.print();
    println!(
        "\n(A healthy w8a8 verifier never demotes; the audit overhead is the\n\
         modeled price of continuously proving the paper's top-1 criterion.)"
    );

    // ---- prefix-cache warm vs cold admission ----------------------------
    // A shared-prefix workload (per-task system-prompt templates) served
    // twice: cold pins the cache off, warm lets admission longest-prefix-
    // match each prompt and prefill only the suffix. Outputs are
    // bit-identical by construction; the win is modeled admission time.
    let plen = mr.cfg().prefill_len / 2;
    let shared = ctx.workloads.shared_prefix(8, plen, &mut Pcg::seeded(0x5A5A))?;
    let mut px_table = TableWriter::new(
        &format!("prefix cache on a shared-prefix workload (8 reqs, {plen}-token templates)"),
        &["prefix cache", "modeled prefill", "hits", "hit tokens", "resident"],
    );
    let mut streams: Vec<Vec<Vec<i32>>> = Vec::new();
    // Budget in model terms rather than raw MiB: room for 32 resident
    // single-row segments of this model's KV shape.
    let budget = 32 * mr.cache_row_bytes(mr.cfg().n_layers);
    for enabled in [false, true] {
        let cfg = EngineConfig {
            prefix: if enabled {
                PrefixCacheConfig { budget_bytes: budget, ..Default::default() }
            } else {
                PrefixCacheConfig::off()
            },
            ..EngineConfig::quasar(1, 5)
        };
        let mut engine = Engine::new(Rc::clone(&mr), cfg)?;
        for it in &shared {
            engine.submit(it.prompt_ids.clone(), bench_params(0.0, 32), &it.task);
        }
        let mut done = engine.run_to_completion()?;
        done.sort_by_key(|c| c.id);
        streams.push(done.into_iter().map(|c| c.tokens).collect());
        let ps = engine.prefix_cache().stats();
        px_table.row(vec![
            if enabled { "on" } else { "off (cold)" }.to_string(),
            format!("{:.4}s", perf.prefill_time(&engine.call_log)),
            ps.hits.to_string(),
            ps.hit_tokens.to_string(),
            format!(
                "{} runs / {} pages / {:.1} KiB",
                ps.segments,
                ps.resident_pages,
                ps.resident_bytes as f64 / 1024.0
            ),
        ]);
    }
    px_table.print();
    println!(
        "\n(Token streams {}: prefix reuse is lossless; the saving is the\n\
         suffix-only prefill's modeled admission traffic.)",
        if streams[0] == streams[1] { "bit-identical" } else { "DIVERGED — BUG" }
    );
    Ok(())
}

//! Speculation-depth sweep: how L and the modeled speedup respond to gamma,
//! for both verifier variants (companion to the Table 3 bench) — plus an
//! occupancy sweep showing the elastic step planner's modeled-traffic win
//! when a batched group runs below capacity.
//!
//! Run: `cargo run --release --example sweep_gamma -- [--task gsm8k]`

use quasar::bench::{prompts_for, run_method, speed, BenchCtx, TableWriter};
use quasar::coordinator::{DrafterKind, EngineConfig};
use quasar::spec::NgramConfig;
use quasar::util::cli::Cli;

fn main() {
    quasar::util::bigstack::run(|| {
        if let Err(e) = run() {
            eprintln!("error: {e:#}");
            std::process::exit(1);
        }
    })
}

fn run() -> anyhow::Result<()> {
    let args = Cli::new("sweep_gamma", "speculation depth sweep")
        .opt("task", Some("gsm8k"), "workload task family")
        .opt("n", Some("4"), "prompts")
        .opt("batch", Some("4"), "batch bucket for the occupancy sweep")
        .parse_env();
    let ctx = BenchCtx::load()?;
    let mr = ctx.model("qwen3-like")?;
    let perf = ctx.perf(&mr);
    let items = prompts_for(&ctx, &args.str("task"), args.usize("n"), 5);
    let base = run_method(&mr, &perf, EngineConfig::vanilla(1), &items, 0.0, 48)?;

    let mut table = TableWriter::new(
        &format!("gamma sweep on {}", args.str("task")),
        &["gamma", "ngram L", "ngram Speed", "quasar L", "quasar Speed"],
    );
    for gamma in [1usize, 2, 3, 5, 7, 9] {
        let mk = |verifier: &str| EngineConfig {
            verifier: verifier.into(),
            drafter: DrafterKind::Ngram(NgramConfig { gamma, adaptive: false, ..Default::default() }),
            batch: 1,
            gamma,
            seed: 0,
            policy: Default::default(),
            elastic: true,
        };
        let ng = run_method(&mr, &perf, mk("fp32"), &items, 0.0, 48)?;
        let qs = run_method(&mr, &perf, mk("w8a8"), &items, 0.0, 48)?;
        table.row(vec![
            gamma.to_string(),
            format!("{:.2}", ng.mean_l()), speed(ng.speedup_vs(&base)),
            format!("{:.2}", qs.mean_l()), speed(qs.speedup_vs(&base)),
        ]);
    }
    table.print();

    // ---- elastic planner vs monolithic at occupancy < batch -------------
    // Submitting fewer prompts than the bucket leaves rows idle; the
    // monolithic engine still streams every KV row of the configured bucket
    // each step, while the planner executes the smallest exported bucket
    // that fits (and splits decode-only rows out when that prices lower).
    let batch = args.usize("batch");
    let mut occ_table = TableWriter::new(
        &format!("elastic planner vs monolithic, batch bucket {batch} (modeled decode s)"),
        &["occupancy", "monolithic", "elastic", "saved"],
    );
    for occupancy in 1..=batch.min(items.len()) {
        let mk = |elastic: bool| EngineConfig {
            elastic,
            ..EngineConfig::quasar(batch, 5)
        };
        let mono = run_method(&mr, &perf, mk(false), &items[..occupancy], 0.0, 48)?;
        let ela = run_method(&mr, &perf, mk(true), &items[..occupancy], 0.0, 48)?;
        occ_table.row(vec![
            format!("{occupancy}/{batch}"),
            format!("{:.4}s", mono.modeled_s),
            format!("{:.4}s", ela.modeled_s),
            format!("{:.1}%", 100.0 * (1.0 - ela.modeled_s / mono.modeled_s.max(1e-12))),
        ]);
    }
    occ_table.print();
    println!(
        "\n(Elastic and monolithic runs commit identical greedy tokens; the\n\
         saving is modeled memory traffic on the simulated device.)"
    );
    Ok(())
}

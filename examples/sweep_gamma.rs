//! Speculation-depth sweep: how L and the modeled speedup respond to gamma,
//! for both verifier variants (companion to the Table 3 bench).
//!
//! Run: `cargo run --release --example sweep_gamma -- [--task gsm8k]`

use quasar::bench::{prompts_for, run_method, speed, BenchCtx, TableWriter};
use quasar::coordinator::{DrafterKind, EngineConfig};
use quasar::spec::NgramConfig;
use quasar::util::cli::Cli;

fn main() {
    quasar::util::bigstack::run(|| {
        if let Err(e) = run() {
            eprintln!("error: {e:#}");
            std::process::exit(1);
        }
    })
}

fn run() -> anyhow::Result<()> {
    let args = Cli::new("sweep_gamma", "speculation depth sweep")
        .opt("task", Some("gsm8k"), "workload task family")
        .opt("n", Some("4"), "prompts")
        .parse_env();
    let ctx = BenchCtx::load()?;
    let mr = ctx.model("qwen3-like")?;
    let perf = ctx.perf(&mr);
    let items = prompts_for(&ctx, &args.str("task"), args.usize("n"), 5);
    let base = run_method(&mr, &perf, EngineConfig::vanilla(1), &items, 0.0, 48)?;

    let mut table = TableWriter::new(
        &format!("gamma sweep on {}", args.str("task")),
        &["gamma", "ngram L", "ngram Speed", "quasar L", "quasar Speed"],
    );
    for gamma in [1usize, 2, 3, 5, 7, 9] {
        let mk = |verifier: &str| EngineConfig {
            verifier: verifier.into(),
            drafter: DrafterKind::Ngram(NgramConfig { gamma, adaptive: false, ..Default::default() }),
            batch: 1,
            gamma,
            seed: 0,
            policy: Default::default(),
        };
        let ng = run_method(&mr, &perf, mk("fp32"), &items, 0.0, 48)?;
        let qs = run_method(&mr, &perf, mk("w8a8"), &items, 0.0, 48)?;
        table.row(vec![
            gamma.to_string(),
            format!("{:.2}", ng.mean_l()), speed(ng.speedup_vs(&base)),
            format!("{:.2}", qs.mean_l()), speed(qs.speedup_vs(&base)),
        ]);
    }
    table.print();
    Ok(())
}
